"""§Roofline source: reads the dry-run artifacts and prints the per-cell
three-term roofline table (compute/memory/collective seconds per step,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS)."""

from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh: str = "pod") -> list[dict]:
    d = ART / mesh
    if not d.exists():
        return []
    return sorted((json.loads(p.read_text()) for p in d.glob("*.json")),
                  key=lambda r: (r["arch"], r["shape"]))


def run() -> list[tuple[str, float, str]]:
    rows = []
    for rec in load_cells("pod"):
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        if rec["status"] == "skipped":
            rows.append((name, 0.0, f"skipped:{rec['reason'][:40]}"))
            continue
        if rec["status"] != "ok" or "roofline" not in rec:
            rows.append((name, 0.0, rec["status"]))
            continue
        r = rec["roofline"]
        mem = rec["memory"]
        ratio = rec.get("useful_flops_ratio")
        rows.append((
            name,
            max(r["compute_s"], r.get("memory_analytic_s", 0), r["collective_s"]) * 1e6,
            f"bottleneck={r['bottleneck']};c={r['compute_s']:.4f};"
            f"m_hlo={r['memory_s']:.4f};m_analytic={r.get('memory_analytic_s', 0):.4f};"
            f"x={r['collective_s']:.4f};useful_flops={ratio:.3f};"
            f"args_GB={mem['argument_bytes'] / 1e9:.2f};temp_GB={mem['temp_bytes'] / 1e9:.2f}"
            if ratio is not None else "no-analysis"))
    # multipod pass/fail summary
    mp = load_cells("multipod")
    ok = sum(r["status"] == "ok" for r in mp)
    sk = sum(r["status"] == "skipped" for r in mp)
    fl = sum(r["status"] == "failed" for r in mp)
    rows.append(("dryrun_multipod_summary", 0.0, f"ok={ok};skipped={sk};failed={fl}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
