"""Paper Fig. 8 analogue: V compression ratio vs KIVI across context lengths.

Since token-wise quantization is shared with KIVI, the improvement is pure
entropy coding; the paper reports up to 83% / avg 62% over KIVI and notes the
ratio is FLAT in context length (per-layer shared codebooks keep working as
the cache grows).  Context lengths 2048–16384 as in the figure.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro import api
from repro.core import quant
from repro.core.codec import kivi_ratio
from repro.core.policy import CompressionPolicy, TensorPolicy

CTX = [2048, 4096, 8192, 16384]
V_SCALES = [0.08, 0.12, 0.15, 0.2]


def run() -> list[tuple[str, float, str]]:
    cfg, params, data = common.get_tiny_lm()
    _, v_all = common.harvest_kv(cfg, params, data, n_tokens=max(CTX))
    rows = []
    for rel in V_SCALES:
        ratios = []
        for ctx in CTX:
            v = jnp.asarray(v_all[:ctx])
            # V report through the facade (layout objects own the accounting)
            r = api.estimate_ratio(v=v, policy=CompressionPolicy(
                layout="huffman", block_size=64,
                v=TensorPolicy(rel_scale=rel)), which="v")["v"]
            q2 = quant.kivi_quantize_v(v, 2)
            rk = kivi_ratio(q2, 2)
            gain = (r.ratio / rk.ratio - 1) * 100
            ratios.append(r.ratio)
            rows.append((f"fig8_v_rel{rel}_ctx{ctx}", 0.0,
                         f"ratio={r.ratio:.3f};kivi2_ratio={rk.ratio:.3f};"
                         f"gain_vs_kivi2_pct={gain:.1f}"))
        flatness = (max(ratios) - min(ratios)) / np.mean(ratios)
        rows.append((f"fig8_v_rel{rel}_ctx_flatness", 0.0,
                     f"rel_spread={flatness:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
