"""Continuous-batching serve throughput: Server vs the legacy bucket engine.

One heterogeneous workload (mixed prompt lengths, mixed token budgets) runs
through both serving paths per cache layout:

  * ``Server`` — slot scheduler, per-row decode positions, requests join and
    leave mid-flight (no lockstep padding waste);
  * ``LockstepEngine`` — the pre-scheduler bucket batcher: groups padded to a
    length grid decode for ``max(max_new_tokens)`` steps each.

Both paths run once for jit warmup and once measured, on the same compiled
closures, so the comparison is steady-state scheduling efficiency rather
than compile time.  Writes ``BENCH_serve.json`` with aggregate tok/s and
live kv-cache bytes per layout — the serving numbers behind the paper's
"throughput-critical inference systems" claim (§5).

    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.models import model as M
from repro.models import registry
from repro.serve.engine import EngineConfig, LockstepEngine, Request
from repro.serve.scheduler import Server, ServerConfig


def make_workload(rng, vocab: int, n_requests: int, base_prompt: int,
                  base_new: int) -> list[Request]:
    """Heterogeneous mix — the traffic continuous batching exists for:
    prompt lengths spread base/6 .. base (several length buckets, unevenly
    filled) and budgets base/6 .. base scattered so every bucket group holds
    at least one long-running request (maximal lockstep masking waste)."""
    n1 = max(n_requests - 1, 1)
    ks = rng.permutation(n_requests)  # scatter budgets across the length order
    reqs = []
    for i in range(n_requests):
        plen = max(4, base_prompt - (base_prompt - base_prompt // 6) * i // n1)
        n_new = max(2, base_new - (base_new - base_new // 6) * int(ks[i]) // n1)
        reqs.append(Request(prompt=rng.integers(0, vocab, plen).astype(np.int32),
                            max_new_tokens=n_new))
    return reqs


def run_server(server: Server, reqs: list[Request]) -> dict:
    handles = [server.submit(r) for r in reqs]
    t0 = time.monotonic()
    server.run()
    wall = time.monotonic() - t0
    results = [h.result() for h in handles]
    toks = sum(len(r.tokens) for r in results)
    return {"wall_s": wall, "tokens": toks, "tok_s": toks / wall,
            "mean_latency_s": float(np.mean([r.prefill_s + r.gen_s
                                             for r in results]))}


def run_lockstep(engine: LockstepEngine, reqs: list[Request]) -> dict:
    t0 = time.monotonic()
    results = engine.generate(reqs)
    wall = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in results)
    return {"wall_s": wall, "tokens": toks, "tok_s": toks / wall}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--layouts", default="raw,packed")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small model, short workload)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--require-speedup", action="store_true",
                    help="exit non-zero unless the server beats the legacy "
                         "bucket engine on every layout (CI gate)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        # small counts, same traffic shape as the default: prompts spanning
        # several length buckets (fragmented legacy groups), a deep scattered
        # decode-budget spread (lockstep masking waste), and queue depth
        # beyond the slot count (continuous refill)
        args.requests = min(args.requests, 10)
        args.prompt_len = min(args.prompt_len, 48)
        args.new_tokens = min(args.new_tokens, 32)
        args.max_seq = min(args.max_seq, 128)

    cfg0 = registry.get_smoke_config(args.arch)
    params, _ = M.init_params(cfg0, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = make_workload(rng, cfg0.vocab_size, args.requests,
                         args.prompt_len, args.new_tokens)
    assert len(reqs) >= 8 or args.requests < 8

    bench = {"arch": args.arch,
             "workload": {"requests": len(reqs),
                          "prompt_lens": [len(r.prompt) for r in reqs],
                          "max_new_tokens": [r.max_new_tokens for r in reqs]},
             "slots": args.slots, "layouts": {}}
    for layout in args.layouts.split(","):
        cfg = dataclasses.replace(cfg0, cache_layout=layout)
        server = Server(cfg, params,
                        ServerConfig(max_slots=args.slots, max_seq=args.max_seq,
                                     policy="ljf"),
                        q_chunk=32, kv_chunk=32)
        legacy = LockstepEngine(cfg, params,
                                EngineConfig(bucket=32, max_batch=args.slots,
                                             max_seq=args.max_seq),
                                q_chunk=32, kv_chunk=32)
        run_server(server, reqs)      # jit warmup (same compiled closures)
        run_lockstep(legacy, reqs)
        # interleaved repeats + median: CPU walls at this scale are noisy,
        # and alternating the engines exposes both to the same drift
        srv_runs, old_runs = [], []
        for _ in range(args.repeats):
            srv_runs.append(run_server(server, reqs))
            old_runs.append(run_lockstep(legacy, reqs))
        srv = sorted(srv_runs, key=lambda r: r["tok_s"])[args.repeats // 2]
        old = sorted(old_runs, key=lambda r: r["tok_s"])[args.repeats // 2]
        srv["kv_cache_bytes"] = server.memory_report()["kv_bytes"]
        entry = {"server": srv, "legacy_bucket": old,
                 "speedup": srv["tok_s"] / old["tok_s"]}
        bench["layouts"][layout] = entry
        print(f"[{layout:8s}] server {srv['tok_s']:7.1f} tok/s  "
              f"legacy {old['tok_s']:7.1f} tok/s  "
              f"speedup {entry['speedup']:.2f}x  "
              f"kv_cache {srv['kv_cache_bytes']:,}B")

    walls = [(v["server"]["wall_s"], v["legacy_bucket"]["wall_s"],
              v["server"]["tokens"]) for v in bench["layouts"].values()]
    agg = (sum(t for _, _, t in walls) / sum(s for s, _, _ in walls)) / \
          (sum(t for _, _, t in walls) / sum(l for _, l, _ in walls))
    bench["aggregate_speedup"] = agg
    Path(args.out).write_text(json.dumps(bench, indent=2))
    print(f"aggregate speedup {agg:.2f}x; wrote {args.out}")
    if args.require_speedup and agg <= 1.0:
        raise SystemExit(
            f"server did not beat the legacy bucket engine in aggregate "
            f"({agg:.2f}x): " +
            str({k: round(v['speedup'], 2) for k, v in bench['layouts'].items()}))


if __name__ == "__main__":
    main()
