"""Continuous-batching serve throughput: Server vs the legacy bucket engine.

One heterogeneous workload (mixed prompt lengths, mixed token budgets) runs
through both serving paths per cache layout:

  * ``Server`` — slot scheduler, per-row decode positions, requests join and
    leave mid-flight (no lockstep padding waste);
  * ``LockstepEngine`` — the pre-scheduler bucket batcher: groups padded to a
    length grid decode for ``max(max_new_tokens)`` steps each.

Both paths run once for jit warmup and once measured, on the same compiled
closures, so the comparison is steady-state scheduling efficiency rather
than compile time.  Writes ``BENCH_serve.json`` with aggregate tok/s,
latency decomposition (queue wait / TTFT / inter-token p50+p99 — per-token
timestamps from ``Result.token_times``), and live kv-cache bytes per
layout — the serving numbers behind the paper's "throughput-critical
inference systems" claim (§5).

A second, mixed long-prompt/short-decode leg (DESIGN.md §13) replays the
tail-latency scenario chunked admission exists for: one long prompt lands
mid-stream over a pool of short decoders, once under ``prefill_mode=
"chunked"`` and once under ``"solo"``.  ``--require-p99-win`` gates the
result (CI): chunked admission must cut the short decoders' p99
inter-token latency at least 2x vs solo at >= 0.9x the aggregate tok/s,
with bit-identical greedy outputs.

    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro import obs
from repro.models import model as M
from repro.models import registry
from repro.serve.engine import EngineConfig, LockstepEngine, Request
from repro.serve.scheduler import Server, ServerConfig


def make_workload(rng, vocab: int, n_requests: int, base_prompt: int,
                  base_new: int) -> list[Request]:
    """Heterogeneous mix — the traffic continuous batching exists for:
    prompt lengths spread base/6 .. base (several length buckets, unevenly
    filled) and budgets base/6 .. base scattered so every bucket group holds
    at least one long-running request (maximal lockstep masking waste)."""
    n1 = max(n_requests - 1, 1)
    ks = rng.permutation(n_requests)  # scatter budgets across the length order
    reqs = []
    for i in range(n_requests):
        plen = max(4, base_prompt - (base_prompt - base_prompt // 6) * i // n1)
        n_new = max(2, base_new - (base_new - base_new // 6) * int(ks[i]) // n1)
        reqs.append(Request(prompt=rng.integers(0, vocab, plen).astype(np.int32),
                            max_new_tokens=n_new))
    return reqs


def _latency_block(results) -> dict:
    """Latency decomposition from per-token timestamps: queue wait split
    out of the old conflated mean latency, TTFT and inter-token gaps as
    p50/p99 (the serving tail the chunked-admission gate watches)."""
    # ttft_s is None for token-less results (failed/cancelled before the
    # first token); exclude them rather than report a fictitious 0.0
    ttfts = [r.ttft_s for r in results if r.ttft_s is not None] or [0.0]
    gaps = np.concatenate([np.diff(r.token_times) for r in results
                           if len(r.token_times) > 1] or [np.zeros(1)])
    return {
        "queue_wait_s": float(np.mean([r.queue_wait_s for r in results])),
        "mean_latency_s": float(np.mean([r.prefill_s + r.gen_s
                                         for r in results])),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "itl_p50_s": float(np.percentile(gaps, 50)),
        "itl_p99_s": float(np.percentile(gaps, 99)),
    }


def run_server(server: Server, reqs: list[Request]) -> dict:
    handles = [server.submit(r) for r in reqs]
    t0 = time.monotonic()
    server.run()
    wall = time.monotonic() - t0
    results = [h.result() for h in handles]
    toks = sum(len(r.tokens) for r in results)
    return {"wall_s": wall, "tokens": toks, "tok_s": toks / wall,
            **_latency_block(results)}


def run_mixed(cfg, params, mode: str, shorts: list[Request],
              long_req: Request, *, slots: int, max_seq: int,
              chunk_tokens: int, pre_steps: int = 3,
              repeats: int = 3) -> dict:
    """One long prompt arriving mid-stream over a pool of short decoders,
    under ``prefill_mode=mode`` on the paged pool (the fused
    encode-to-page admission path).  The short decoders' inter-token gaps
    are the measurement: solo admission freezes them for the long
    prompt's whole prefill, chunked admission bounds every stall at
    ``prefill_chunk_tokens``."""
    server = Server(cfg, params,
                    ServerConfig(max_slots=slots, max_seq=max_seq,
                                 cache_mode="paged", prefill_mode=mode,
                                 prefill_chunk_tokens=chunk_tokens),
                    q_chunk=32, kv_chunk=32)

    def once():
        hs = [server.submit(r) for r in shorts]
        t0 = time.monotonic()
        for _ in range(pre_steps):   # the decoders are mid-stream...
            server.step()
        hl = server.submit(long_req)  # ...when the long prompt lands
        server.run()
        wall = time.monotonic() - t0
        return hs, hl, wall

    once()  # jit warmup on the same compiled closures
    # median-of-repeats: the short decoders' p99 inter-token gap is a tail
    # statistic, exactly what single-shot CPU walls scatter the most
    runs = sorted((once() for _ in range(repeats)), key=lambda r: r[2])
    hs, hl, wall = runs[len(runs) // 2]
    short_res = [h.result() for h in hs]
    long_res = hl.result()
    toks = sum(len(r.tokens) for r in short_res) + len(long_res.tokens)
    return {"wall_s": wall, "tokens": toks, "tok_s": toks / wall,
            "long_ttft_s": long_res.ttft_s,
            "long_queue_wait_s": long_res.queue_wait_s,
            "stalled_decode_steps":
                server.stats()["prefill"]["stalled_decode_steps"],
            "coscheduled_tokens":
                server.stats()["prefill"]["coscheduled_tokens"],
            **{f"short_{k}": v for k, v in _latency_block(short_res).items()},
            "outputs": [r.tokens.tolist()
                        for r in short_res] + [long_res.tokens.tolist()]}


def run_lockstep(engine: LockstepEngine, reqs: list[Request]) -> dict:
    t0 = time.monotonic()
    results = engine.generate(reqs)
    wall = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in results)
    return {"wall_s": wall, "tokens": toks, "tok_s": toks / wall}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--layouts", default="raw,packed")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small model, short workload)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--require-speedup", action="store_true",
                    help="exit non-zero unless the server beats the legacy "
                         "bucket engine on every layout (CI gate)")
    ap.add_argument("--long-prompt", type=int, default=8192,
                    help="long-prompt length for the mixed leg (8-32k "
                         "nominal; --smoke shrinks it)")
    ap.add_argument("--require-p99-win", action="store_true",
                    help="exit non-zero unless chunked admission cuts the "
                         "mixed leg's p99 inter-token latency >=2x vs solo "
                         "at >=0.9x aggregate tok/s (CI gate)")
    ap.add_argument("--trace", default="off",
                    choices=("off", "events", "full"),
                    help="scheduler event-trace level for the Server runs "
                         "(DESIGN.md §14); 'off' keeps the hot path "
                         "event-free")
    ap.add_argument("--trace-out", default=None,
                    help="write the last layout server's Chrome trace-event "
                         "JSON here (needs --trace events|full)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the last layout server's metrics snapshot "
                         "(JSON + .prom exposition sibling) here")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler device trace of the "
                         "measured layout runs into this directory")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        # small counts, same traffic shape as the default: prompts spanning
        # several length buckets (fragmented legacy groups), a deep scattered
        # decode-budget spread (lockstep masking waste), and queue depth
        # beyond the slot count (continuous refill)
        args.requests = min(args.requests, 10)
        args.prompt_len = min(args.prompt_len, 48)
        args.new_tokens = min(args.new_tokens, 32)
        args.max_seq = min(args.max_seq, 128)

    cfg0 = registry.get_smoke_config(args.arch)
    params, _ = M.init_params(cfg0, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = make_workload(rng, cfg0.vocab_size, args.requests,
                         args.prompt_len, args.new_tokens)
    assert len(reqs) >= 8 or args.requests < 8

    bench = {"arch": args.arch,
             "workload": {"requests": len(reqs),
                          "prompt_lens": [len(r.prompt) for r in reqs],
                          "max_new_tokens": [r.max_new_tokens for r in reqs]},
             "slots": args.slots, "layouts": {}}
    server = None
    with obs.trace_capture(args.profile_dir):
        for layout in args.layouts.split(","):
            cfg = dataclasses.replace(cfg0, cache_layout=layout)
            server = Server(cfg, params,
                            ServerConfig(max_slots=args.slots,
                                         max_seq=args.max_seq,
                                         policy="ljf", trace=args.trace),
                            q_chunk=32, kv_chunk=32)
            legacy = LockstepEngine(cfg, params,
                                    EngineConfig(bucket=32,
                                                 max_batch=args.slots,
                                                 max_seq=args.max_seq),
                                    q_chunk=32, kv_chunk=32)
            run_server(server, reqs)  # jit warmup (same compiled closures)
            run_lockstep(legacy, reqs)
            # interleaved repeats + median: CPU walls at this scale are
            # noisy, and alternating the engines exposes both to the same
            # drift
            srv_runs, old_runs = [], []
            for _ in range(args.repeats):
                srv_runs.append(run_server(server, reqs))
                old_runs.append(run_lockstep(legacy, reqs))
            srv = sorted(srv_runs, key=lambda r: r["tok_s"])[args.repeats // 2]
            old = sorted(old_runs, key=lambda r: r["tok_s"])[args.repeats // 2]
            srv["kv_cache_bytes"] = server.memory_report()["kv_bytes"]
            entry = {"server": srv, "legacy_bucket": old,
                     "speedup": srv["tok_s"] / old["tok_s"]}
            bench["layouts"][layout] = entry
            print(f"[{layout:8s}] server {srv['tok_s']:7.1f} tok/s  "
                  f"legacy {old['tok_s']:7.1f} tok/s  "
                  f"speedup {entry['speedup']:.2f}x  "
                  f"kv_cache {srv['kv_cache_bytes']:,}B")
    # Registry-sourced columns (last layout's server): what run.py splices
    # into its CSV rows and the CI artifacts expose.
    bench["metrics"] = obs.bench_columns(server)
    if args.metrics_out or args.trace_out:
        server.shutdown(metrics_out=args.metrics_out,
                        trace_out=args.trace_out)

    walls = [(v["server"]["wall_s"], v["legacy_bucket"]["wall_s"],
              v["server"]["tokens"]) for v in bench["layouts"].values()]
    agg = (sum(t for _, _, t in walls) / sum(s for s, _, _ in walls)) / \
          (sum(t for _, _, t in walls) / sum(l for _, l, _ in walls))
    bench["aggregate_speedup"] = agg

    # -- mixed long-prompt/short-decode leg (chunked vs solo admission) -----
    mix_cfg = dataclasses.replace(cfg0, cache_layout="packed")
    T = M.cache_specs(mix_cfg, 1)[0].block_size
    long_len = (min(args.long_prompt, 30 * T) if args.smoke
                else args.long_prompt)
    long_len -= long_len % T  # block-multiple keeps the chunk count exact
    mix_seq = long_len + 4 * T + 16
    rng2 = np.random.default_rng(1)
    shorts = [Request(prompt=rng2.integers(0, mix_cfg.vocab_size,
                                           8 + 2 * i).astype(np.int32),
                      max_new_tokens=40) for i in range(args.slots - 1)]
    long_req = Request(prompt=rng2.integers(0, mix_cfg.vocab_size,
                                            long_len).astype(np.int32),
                       max_new_tokens=8)
    legs = {mode: run_mixed(mix_cfg, params, mode, shorts, long_req,
                            slots=args.slots, max_seq=mix_seq,
                            chunk_tokens=2 * T)
            for mode in ("chunked", "solo")}
    match = legs["chunked"].pop("outputs") == legs["solo"].pop("outputs")
    p99_ratio = (legs["solo"]["short_itl_p99_s"]
                 / max(legs["chunked"]["short_itl_p99_s"], 1e-9))
    tok_ratio = legs["chunked"]["tok_s"] / legs["solo"]["tok_s"]
    bench["mixed_long_prompt"] = {
        "long_prompt_len": long_len, "chunk_tokens": 2 * T,
        "short_requests": len(shorts), "bit_identical": match,
        "p99_itl_improvement": p99_ratio, "tok_s_ratio": tok_ratio,
        **{mode: leg for mode, leg in legs.items()},
    }
    print(f"[mixed   ] long={long_len} tok: p99 ITL "
          f"{legs['solo']['short_itl_p99_s'] * 1e3:.1f}ms solo -> "
          f"{legs['chunked']['short_itl_p99_s'] * 1e3:.1f}ms chunked "
          f"({p99_ratio:.2f}x better) at {tok_ratio:.2f}x tok/s, "
          f"bit_identical={match}")

    Path(args.out).write_text(json.dumps(bench, indent=2))
    print(f"aggregate speedup {agg:.2f}x; wrote {args.out}")
    if args.require_speedup and agg <= 1.0:
        raise SystemExit(
            f"server did not beat the legacy bucket engine in aggregate "
            f"({agg:.2f}x): " +
            str({k: round(v['speedup'], 2) for k, v in bench['layouts'].items()}))
    if args.require_p99_win and not (
            match and p99_ratio >= 2.0 and tok_ratio >= 0.9):
        raise SystemExit(
            "chunked admission failed the mixed-leg gate: "
            f"p99 ITL improvement {p99_ratio:.2f}x (need >=2), tok/s ratio "
            f"{tok_ratio:.2f} (need >=0.9), bit_identical={match}")


if __name__ == "__main__":
    main()
