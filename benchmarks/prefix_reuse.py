"""Prefix-reuse bench: shared-prompt serving with the compressed-page
prefix cache on vs off (DESIGN.md §11).

The workload is N requests that share one long block-aligned system prompt
and diverge only in a short unique suffix — the multi-tenant chat shape the
prefix cache is built for.  Both runs use the SAME paged configuration and
the SAME block-chunked admission numerics; the only difference is whether
the radix index may splice cached page ids into a new row:

  * ``prefix_cache="on"``      — admission looks up the shared prefix and
    prefills only the divergent suffix;
  * ``prefix_cache="noshare"`` — identical chunked admission with the index
    disabled (every request prefills its full prompt).

Because both modes chunk the forced tokens identically, greedy outputs are
bit-identical by construction — the bench asserts it, so the reported
savings are at EQUAL outputs, not merely similar ones.  Records per mode:

  * ``tok_s``                — aggregate decode throughput,
  * ``prefill_tokens``       — tokens actually pushed through prefill,
  * ``reused_tokens``        — tokens spliced from cached pages,
  * ``prefill_flops``        — analytic FLOPs from the model dims: linear
    cost ``2 * param_count`` per prefill token plus attention cost
    ``4 * n_layers * n_heads * head_dim`` per attended (q, kv) pair
    (the scheduler counts the pairs exactly).

Writes ``BENCH_prefix.json``.  ``--require-savings`` exits non-zero unless
sharing saves >= 2x prefill FLOPs at bit-identical tokens (the CI gate).

    PYTHONPATH=src python benchmarks/prefix_reuse.py --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro import obs
from repro.models import model as M
from repro.models import registry
from repro.serve.scheduler import Request, Server, ServerConfig


def make_workload(rng, vocab: int, n_requests: int, shared_len: int,
                  suffix_len: int, new_tokens: int) -> list[Request]:
    """One shared system prompt, unique per-request suffixes."""
    shared = rng.integers(0, vocab, shared_len).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        suffix = rng.integers(0, vocab, suffix_len).astype(np.int32)
        reqs.append(Request(prompt=np.concatenate([shared, suffix]),
                            max_new_tokens=new_tokens))
    return reqs


def prefill_flops(cfg, prefix_stats: dict) -> int:
    """Analytic prefill FLOPs from the scheduler's exact host counters."""
    linear = 2 * cfg.param_count() * prefix_stats["prefill_tokens"]
    attn = (4 * cfg.n_layers * cfg.n_heads * cfg.resolved_head_dim
            * prefix_stats["prefill_attn_pairs"])
    return linear + attn


def run_mode(cfg, params, reqs, mode: str, max_slots: int, max_seq: int,
             pool_bytes: int | None) -> tuple[dict, list[np.ndarray]]:
    server = Server(cfg, params,
                    ServerConfig(max_slots=max_slots, max_seq=max_seq,
                                 cache_mode="paged",
                                 pool_hbm_bytes=pool_bytes,
                                 prefix_cache=mode),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(r) for r in reqs]
    t0 = time.monotonic()
    server.run()
    wall = time.monotonic() - t0
    outs = [np.asarray(h.result().tokens) for h in handles]
    toks = sum(len(o) for o in outs)
    st = server.stats()
    px = st["prefix"]
    entry = {"tokens": toks, "wall_s": wall, "tok_s": toks / wall,
             "prefill_tokens": px["prefill_tokens"],
             "prefill_attn_pairs": px["prefill_attn_pairs"],
             "reused_tokens": px["reused_tokens"],
             "hit_rate": px["hit_rate"] if mode == "on" else 0.0,
             "prefill_flops": prefill_flops(cfg, px),
             "preemptions": st["preemptions"],
             "metrics": obs.bench_columns(server)}
    return entry, outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--layout", default="packed")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--shared-blocks", type=int, default=12,
                    help="shared system-prompt length in cache blocks")
    ap.add_argument("--suffix-len", type=int, default=6,
                    help="unique per-request prompt suffix (tokens)")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small model, short workload)")
    ap.add_argument("--require-savings", action="store_true",
                    help="exit non-zero unless sharing saves >= 2x prefill "
                         "FLOPs at bit-identical greedy tokens (CI gate)")
    ap.add_argument("--out", default="BENCH_prefix.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 6)
        args.shared_blocks = min(args.shared_blocks, 8)
        args.new_tokens = min(args.new_tokens, 6)

    cfg0 = registry.get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg0, cache_layout=args.layout, cache_block=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared_len = args.shared_blocks * 8
    reqs = make_workload(rng, cfg.vocab_size, args.requests, shared_len,
                         args.suffix_len, args.new_tokens)

    bench = {"arch": args.arch, "layout": args.layout,
             "workload": {"requests": len(reqs),
                          "shared_prefix_tokens": shared_len,
                          "suffix_tokens": args.suffix_len,
                          "new_tokens": args.new_tokens},
             "modes": {}}
    outs = {}
    # Pool left at its dense-equivalent default: ample, so no preemption
    # perturbs the wall-clock comparison.
    for mode in ("noshare", "on"):
        entry, outs[mode] = run_mode(cfg, params, reqs, mode,
                                     args.max_slots, args.max_seq, None)
        bench["modes"][mode] = entry
        print(f"[{mode:8s}] prefill_tokens={entry['prefill_tokens']:5d}  "
              f"reused_tokens={entry['reused_tokens']:5d}  "
              f"prefill_flops={entry['prefill_flops']:.3e}  "
              f"decode {entry['tok_s']:6.1f} tok/s")

    identical = (len(outs["on"]) == len(outs["noshare"]) and
                 all(a.shape == b.shape and bool((a == b).all())
                     for a, b in zip(outs["on"], outs["noshare"])))
    saved = (bench["modes"]["noshare"]["prefill_flops"]
             / max(bench["modes"]["on"]["prefill_flops"], 1))
    bench["bit_identical"] = identical
    bench["prefill_flops_saved_x"] = saved
    # registry-sourced columns for run.py's CSV (the sharing leg)
    bench["metrics"] = bench["modes"]["on"]["metrics"]
    print(f"bit_identical={identical}  prefill_flops_saved=x{saved:.2f}")

    Path(args.out).write_text(json.dumps(bench, indent=2))
    print(f"wrote {args.out}")
    if args.require_savings:
        if not identical:
            raise SystemExit(
                "greedy outputs differ between prefix_cache=on and noshare "
                "— sharing must not change tokens")
        if saved < 2.0:
            raise SystemExit(
                f"prefix sharing saved only x{saved:.2f} prefill FLOPs "
                "(gate requires >= x2.0)")


if __name__ == "__main__":
    main()
