"""Paper Fig. 5 + 6 analogue: accuracy vs relative quantization scale.

K-standalone (BlockQuant vs ChannelQuant), V-standalone (TokenQuant), and
the combined sweep — measured as next-token top-1 agreement with the
uncompressed-cache model and ΔCE, on the tiny LM trained on real text
(DESIGN.md §6 accuracy-proxy note).  The deliverable is the *turning point*
phenomenology: accuracy flat, then cliff.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import model as M

K_SCALES = [0.02, 0.05, 0.08, 0.12, 0.2, 0.35, 0.5]
V_SCALES = [0.05, 0.1, 0.15, 0.25, 0.4, 0.6]
N_EVAL_SEQ = 8
PREFIX = 64
DECODE = 32


def _eval_agreement(cfg_ref, cfg_q, params, data) -> tuple[float, float]:
    """(top-1 agreement, ΔCE) of compressed vs raw decode over text."""
    jax.clear_caches()  # bound the executable cache across configs
    batch = data.batch_at(777)
    toks = batch["tokens"][:N_EVAL_SEQ]
    agree, dce = [], []
    for cfg in (cfg_ref, cfg_q):
        prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b, common.SEQ * 2,
                                                 q_chunk=64, kv_chunk=64))
        decode = jax.jit(lambda p, t, pos, st: M.decode_step(p, cfg, t, pos, st))
        _, state = prefill(params, {"tokens": jnp.asarray(toks[:, :PREFIX])})
        preds, lls = [], []
        cur = jnp.asarray(toks[:, PREFIX])
        pos = PREFIX
        for t in range(DECODE):
            lg, state = decode(params, cur, jnp.asarray(pos, jnp.int32), state)
            logp = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
            preds.append(np.asarray(jnp.argmax(lg, -1)))
            nxt = jnp.asarray(toks[:, PREFIX + t + 1])
            lls.append(float(jnp.take_along_axis(logp, nxt[:, None], 1).mean()))
            cur = nxt
            pos += 1
        if cfg is cfg_ref:
            ref_preds, ref_ce = np.stack(preds), -np.mean(lls)
        else:
            q_preds, q_ce = np.stack(preds), -np.mean(lls)
    return float((ref_preds == q_preds).mean()), float(q_ce - ref_ce)


def run() -> list[tuple[str, float, str]]:
    cfg, params, data = common.get_tiny_lm()
    raw = dataclasses.replace(cfg, cache_layout="raw")
    rows = []

    # --- K standalone (V exact): BlockQuant (ours) ---
    for rel in K_SCALES:
        # V at 8-bit (rel=1/255) ~= exact: isolates K's effect (Fig. 5 left)
        q = dataclasses.replace(cfg, cache_layout="packed", rel_scale_k=rel,
                                rel_scale_v=1 / 255)
        agree, dce = _eval_agreement(raw, q, params, data)
        rows.append((f"fig5_k_block_rel{rel}", 0.0,
                     f"agree={agree:.4f};dce={dce:+.4f}"))

    # --- V standalone (K ~exact) ---
    for rel in V_SCALES:
        q = dataclasses.replace(cfg, cache_layout="packed", rel_scale_v=rel,
                                rel_scale_k=1 / 255)  # K at 8-bit ~= exact
        agree, dce = _eval_agreement(raw, q, params, data)
        rows.append((f"fig5_v_token_rel{rel}", 0.0,
                     f"agree={agree:.4f};dce={dce:+.4f}"))

    # --- combined at the paper's fixed K:V ratio (Fig. 6) ---
    for rel_k in (0.02, 0.05, 0.08, 0.12):
        rel_v = rel_k * 3  # paper fixes the K:V ratio from Fig. 5 turning points
        q = dataclasses.replace(cfg, cache_layout="packed",
                                rel_scale_k=rel_k, rel_scale_v=rel_v)
        agree, dce = _eval_agreement(raw, q, params, data)
        rows.append((f"fig6_combined_k{rel_k}_v{rel_v:.2f}", 0.0,
                     f"agree={agree:.4f};dce={dce:+.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
