"""Pool-pressure capacity bench: paged vs dense admission under one byte
budget (DESIGN.md §10).

Dense mode reserves ``n_blocks`` ring blocks per slot up front, so a byte
budget admits ``budget // (n_blocks * page_bytes)`` concurrent requests no
matter how little of the ring each request uses.  The paged pool admits by
actual post-compression occupancy, so the same budget holds more concurrent
requests — the footprint-to-throughput coupling the paper's compression
ratio buys.  For each budget (in dense-reservation units) and layout this
bench runs the same heterogeneous workload through both modes and records:

  * ``admitted_peak``   — max simultaneously live requests,
  * ``tok_s``           — aggregate decode throughput,
  * ``preemptions`` / pool high-water (paged).

Writes ``BENCH_pool.json``.  ``--require-capacity-win`` exits non-zero
unless, at every budget, the paged server admits STRICTLY more concurrent
requests than dense for a compressing layout (the CI gate).

    PYTHONPATH=src python benchmarks/pool_pressure.py --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro import obs
from repro.core import pool as blockpool
from repro.models import model as M
from repro.models import registry
from repro.serve.scheduler import Request, Server, ServerConfig


def make_workload(rng, vocab: int, n_requests: int, prompt_len: int,
                  new_tokens: int) -> list[Request]:
    """Short-lived heterogeneous requests: each needs a small fraction of a
    full dense ring, which is exactly the traffic a paged pool packs."""
    reqs = []
    for i in range(n_requests):
        plen = max(4, prompt_len - (i * prompt_len // 2) // max(n_requests - 1, 1))
        n_new = max(2, new_tokens - ((i * 7) % new_tokens) // 2)
        reqs.append(Request(prompt=rng.integers(0, vocab, plen).astype(np.int32),
                            max_new_tokens=n_new))
    return reqs


def run_mode(cfg, params, reqs, mode: str, budget: int, max_slots: int,
             max_seq: int) -> dict:
    server = Server(cfg, params,
                    ServerConfig(max_slots=max_slots, max_seq=max_seq,
                                 policy="ljf", cache_mode=mode,
                                 pool_hbm_bytes=budget if mode == "paged" else None),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(r) for r in reqs]
    peak = 0
    t0 = time.monotonic()
    while server.step():
        peak = max(peak, server.active)
    wall = time.monotonic() - t0
    toks = sum(len(h.result().tokens) for h in handles)
    out = {"admitted_peak": peak, "tokens": toks, "wall_s": wall,
           "tok_s": toks / wall,
           "metrics": obs.bench_columns(server)}
    st = server.stats()
    if "pool" in st:
        out["preemptions"] = st["preemptions"]
        out["pool_pages"] = st["pool"]["pages_total"]
        out["pool_high_water_pages"] = st["pool"]["high_water_pages"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--layouts", default="packed,raw")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=10)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--budgets", default="2,3",
                    help="byte budgets in dense-reservation units")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small model, short workload)")
    ap.add_argument("--require-capacity-win", action="store_true",
                    help="exit non-zero unless paged admits strictly more "
                         "concurrent requests than dense at every budget "
                         "for a compressing layout (CI gate)")
    ap.add_argument("--out", default="BENCH_pool.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.budgets = "2"

    cfg0 = registry.get_smoke_config(args.arch)
    params, _ = M.init_params(cfg0, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = make_workload(rng, cfg0.vocab_size, args.requests,
                         args.prompt_len, args.new_tokens)

    bench = {"arch": args.arch,
             "workload": {"requests": len(reqs),
                          "prompt_lens": [len(r.prompt) for r in reqs],
                          "max_new_tokens": [r.max_new_tokens for r in reqs]},
             "layouts": {}}
    compressing_wins = []
    for layout in args.layouts.split(","):
        cfg = dataclasses.replace(cfg0, cache_layout=layout, cache_block=8)
        specs = M.cache_specs(cfg, args.max_seq)
        page_b = sum(blockpool.page_nbytes(s, cfg.n_kv_heads,
                                           cfg.resolved_head_dim)
                     for s in specs)
        reservation_b = specs[0].n_blocks * page_b  # one dense slot's bytes
        entry = {"page_bytes": page_b, "dense_reservation_bytes": reservation_b,
                 "budgets": {}}
        for units in (int(u) for u in args.budgets.split(",")):
            budget = units * reservation_b
            dense_slots = budget // reservation_b
            dense = run_mode(cfg, params, reqs, "dense", budget,
                             max_slots=dense_slots, max_seq=args.max_seq)
            paged = run_mode(cfg, params, reqs, "paged", budget,
                             max_slots=len(reqs), max_seq=args.max_seq)
            ratio = paged["admitted_peak"] / max(dense["admitted_peak"], 1)
            entry["budgets"][f"{units}x"] = {
                "budget_bytes": budget, "dense": dense, "paged": paged,
                "capacity_ratio": ratio,
                "tok_s_ratio": paged["tok_s"] / dense["tok_s"],
            }
            if layout != "raw":
                compressing_wins.append(
                    (layout, units, paged["admitted_peak"],
                     dense["admitted_peak"]))
            print(f"[{layout:8s} {units}x] budget={budget:>9,}B  "
                  f"dense admits {dense['admitted_peak']:2d} "
                  f"@ {dense['tok_s']:6.1f} tok/s  "
                  f"paged admits {paged['admitted_peak']:2d} "
                  f"@ {paged['tok_s']:6.1f} tok/s  "
                  f"capacity x{ratio:.2f}  "
                  f"preempt={paged.get('preemptions', 0)}")
        bench["layouts"][layout] = entry
        # registry-sourced columns for run.py's CSV (last paged leg)
        bench["metrics"] = paged["metrics"]

    Path(args.out).write_text(json.dumps(bench, indent=2))
    print(f"wrote {args.out}")
    if args.require_capacity_win:
        losses = [(lay, u, p, d) for lay, u, p, d in compressing_wins
                  if p <= d]
        if losses:
            raise SystemExit(
                "paged admission did not beat dense reservation at the same "
                f"byte budget: {losses}")


if __name__ == "__main__":
    main()
