"""Paper Fig. 9 analogue: single fused kernel (decompress + matvec) vs the
multi-kernel pipeline (decode → dequantize → matvec), across context lengths
and quantization scales.

Two measurements per point:
  * measured CPU wall time of the jitted XLA paths (RELATIVE comparison —
    absolute numbers are CPU, not TPU);
  * the modeled HBM bytes each path moves on TPU (the quantity that decides
    the paper's Fig. 9 on real hardware): the fused path reads packed words
    once; the multi-kernel path reads packed words, writes decompressed bf16
    to HBM, then reads it back for the matvec.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import bitpack, cache as C
from repro.kernels import ops, ref

CTX = [2048, 4096, 8192, 16384]
REL = [(0.05, 0.15), (0.12, 0.3)]
B, Hkv, G, D, T = 4, 4, 2, 64, 64


def _mk_cache(rng, spec, S):
    k = jnp.asarray(rng.standard_t(4, (B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_t(4, (B, Hkv, S, D)).astype(np.float32))
    return C.prefill(spec, k, v)


def _multi_kernel(cache):
    """The standalone pipeline: decompress to 'HBM' (materialized array),
    then attend over the raw tensors — two extra full passes."""
    spec = cache.spec

    @jax.jit
    def run(c, q):
        kd, vd = spec.impl.fetch(spec, c)  # materialized (HBM writeback)
        B_, H_, NB, T_, D_ = kd.shape
        kr = kd.reshape(B_, H_, NB * T_, D_)
        vr = vd.reshape(B_, H_, NB * T_, D_)
        # plus the raw buffer
        kr = jnp.concatenate([kr, c.k_buf], axis=2)
        vr = jnp.concatenate([vr, c.v_buf], axis=2)
        valid = (jnp.minimum(c.n_flushed, spec.n_blocks)
                 * spec.block_size + c.buf_len)  # [B] per-row
        mask = jnp.arange(kr.shape[2])[None, :] < valid[:, None]
        s = jnp.einsum("bhgd,bhsd->bhgs",
                       q.reshape(B, Hkv, G, D).astype(jnp.float32),
                       kr.astype(jnp.float32)) / np.sqrt(D)
        s = jnp.where(mask[:, None, None], s, -1e9)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgs,bhsd->bhgd", w, vr.astype(jnp.float32))
        return o.reshape(B, Hkv * G, D)

    return run


def _hbm_bytes(spec: C.CacheSpec, S: int, fused: bool) -> int:
    """Modeled bytes the packed part moves per decode step on TPU."""
    NB = S // spec.block_size
    words = NB * (spec.words_k(D) + spec.words_v(D)) * 4 * B * Hkv
    scales = NB * (2 * D + 2 * spec.block_size) * 2 * B * Hkv
    packed_read = words + scales
    if fused:
        return packed_read  # consumed in VMEM/registers
    decompressed = 2 * B * Hkv * NB * spec.block_size * D * 2  # bf16 K+V
    return packed_read + 2 * decompressed  # write back + read for matvec


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    timer = common.Timer()
    rows = []
    for rel_k, rel_v in REL:
        for S in CTX:
            spec = C.CacheSpec(layout="packed", block_size=T, max_seq=S,
                               rel_scale_k=rel_k, rel_scale_v=rel_v)
            cache = _mk_cache(rng, spec, S)
            q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))

            fused = jax.jit(lambda c, qq: ops.cache_decode_attention(
                c, qq, impl="xla"))
            multi = _multi_kernel(cache)
            t_fused = timer.us(fused, cache, q)
            t_multi = timer.us(multi, cache, q)
            o1, o2 = fused(cache, q), multi(cache, q)
            err = float(jnp.max(jnp.abs(o1 - o2)))
            by_f = _hbm_bytes(spec, S, True)
            by_m = _hbm_bytes(spec, S, False)
            raw_bytes = 2 * B * Hkv * S * D * 2
            # equivalent decompression throughput: raw bytes / fused time
            eq_tput = raw_bytes / (t_fused * 1e-6) / 1e9
            rows.append((
                f"fig9_ctx{S}_k{rel_k}", t_fused,
                f"multi_us={t_multi:.0f};speedup={t_multi / t_fused:.2f};"
                f"hbm_fused_MB={by_f / 1e6:.1f};hbm_multi_MB={by_m / 1e6:.1f};"
                f"hbm_ratio={by_m / by_f:.2f};"
                f"eq_decomp_GBps_cpu={eq_tput:.2f};allclose={err < 5e-2}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
