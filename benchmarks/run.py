"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV plus the registry-sourced serving
columns (``repro.obs.BENCH_COLUMNS``: TTFT/ITL p50+p99, preemptions,
copy-on-write breaks — read from each serving suite's ``BENCH_*.json``
``"metrics"`` block; figure suites leave them empty).

    PYTHONPATH=src python -m benchmarks.run                # everything
    PYTHONPATH=src python -m benchmarks.run --only ratio_k # one figure
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.obs import BENCH_COLUMNS

SUITES = [
    ("accuracy_sweep", "paper Fig. 5/6: accuracy vs rel quant scale"),
    ("ratio_k", "paper Fig. 7: K ratio vs KIVI/ChannelQuant"),
    ("ratio_v", "paper Fig. 8: V ratio vs ctx length"),
    ("fused_vs_multi", "paper Fig. 9: fused vs multi-kernel"),
    ("fused_vs_matvec", "paper Fig. 10/11: fused vs plain matvec"),
    ("roofline", "dry-run roofline table"),
    ("serve_throughput", "continuous-batching serving throughput, chunked-prefill"
     " p99 inter-token latency (mixed long-prompt leg)"),
    ("decode_path", "decode-path latency breakdown"),
    ("pool_pressure", "paged-pool capacity vs dense reservation (§10)"),
    ("prefix_reuse", "prefix-cache prefill savings, on vs noshare (§11)"),
    ("shard_scaling", "mesh capacity at equal per-device budget (§12)"),
]


# Which BENCH_*.json each script suite writes — where its registry-sourced
# CSV columns come from (obs.bench_columns embedded under "metrics").
BENCH_JSON = {
    "serve_throughput": "BENCH_serve.json",
    "decode_path": "BENCH_decode.json",
    "pool_pressure": "BENCH_pool.json",
    "prefix_reuse": "BENCH_prefix.json",
    "shard_scaling": "BENCH_shard.json",
}


def metric_cols(mod_name: str) -> str:
    """The trailing CSV cells for one suite row: values from the suite's
    ``BENCH_*.json`` ``"metrics"`` block in ``BENCH_COLUMNS`` order, empty
    cells when the suite has no serving registry behind it."""
    path = BENCH_JSON.get(mod_name)
    if path and os.path.exists(path):
        m = json.loads(open(path).read()).get("metrics") or {}
    else:
        m = {}

    def cell(k):
        v = m.get(k)
        if v is None:
            return ""
        return str(v) if isinstance(v, int) else f"{v:.6g}"

    return "".join("," + cell(k) for k in BENCH_COLUMNS)


def run_one(mod_name: str) -> int:
    """Run one suite in-process (used by the per-suite subprocess).

    Two suite shapes: figure modules expose a ``run()`` generator of
    ``(name, us, derived)`` rows; serving suites are argparse scripts
    (``main()`` + ``--smoke``) that write their own ``BENCH_*.json`` — those
    run under ``--smoke`` and report one pass/fail CSV row here.
    """
    empty = "," * len(BENCH_COLUMNS)
    mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
    if hasattr(mod, "run"):
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}{empty}", flush=True)
        return 0
    argv, sys.argv = sys.argv, [f"benchmarks/{mod_name}.py", "--smoke"]
    try:
        t0 = time.time()
        mod.main()
        print(f"{mod_name},{(time.time() - t0) * 1e6:.1f},smoke_ok"
              f"{metric_cols(mod_name)}", flush=True)
    finally:
        sys.argv = argv
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--in-process", action="store_true",
                    help="run suites in this process (default: one fresh "
                         "subprocess per suite — jitted-executable caches "
                         "otherwise accumulate past this container's RAM)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived," + ",".join(BENCH_COLUMNS), flush=True)
    failures = 0
    for mod_name, desc in SUITES:
        if want and mod_name not in want:
            continue
        t0 = time.time()
        if args.in_process:
            try:
                run_one(mod_name)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"{mod_name}_FAILED,0,{type(e).__name__}:{e}"
                      + "," * len(BENCH_COLUMNS), flush=True)
        else:
            code = (
                "from benchmarks.run import run_one; "
                f"run_one({mod_name!r})"
            )
            env = dict(os.environ)
            env.setdefault("PYTHONPATH", "src")
            r = subprocess.run([sys.executable, "-c", code], env=env,
                               stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
                               text=True)
            sys.stdout.write("\n".join(
                l for l in r.stdout.splitlines() if "," in l and not l.startswith("#")
            ) + ("\n" if r.stdout else ""))
            sys.stdout.flush()
            if r.returncode != 0:
                failures += 1
                print(f"{mod_name}_FAILED,0,subprocess_exit_{r.returncode}"
                      + "," * len(BENCH_COLUMNS), flush=True)
        print(f"# {mod_name} ({desc}) took {time.time() - t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
