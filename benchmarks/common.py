"""Shared benchmark substrate: one tiny byte-level LM trained on real text,
whose harvested KV tensors drive the accuracy/ratio experiments (the CPU-
scale stand-in for the paper's Llama2/Ministral + CoQA/GSM8K setup — see
DESIGN.md §6 accuracy-proxy note)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.tiny_lm import (  # noqa: F401  (re-exported for benchmarks)
    CKPT,
    SEQ,
    STEPS,
    TINY,
    get_tiny_lm,
)
from repro.models import model as M


def harvest_kv(cfg, params, data, n_tokens: int = 8192, seed_step: int = 1000):
    """Run the model over text and capture one layer's pre-cache K/V
    ([ctx, heads, head_dim]) — the statistics source for ratio benchmarks."""
    from repro.models import attention

    B = max(1, n_tokens // SEQ)
    batch = data.batch_at(seed_step)
    toks = jnp.asarray(batch["tokens"][:B])

    captured = {}

    def capture_layer(params_blocks, x, positions):
        block_p = jax.tree.map(lambda p: p[cfg.n_layers // 2], params_blocks)
        from repro.models import layers as L

        h = L.rms_norm(x, block_p["ln_attn"], cfg.norm_eps)
        q, k, v = attention.qkv_project(block_p["attn"], cfg, h, positions)
        return k, v

    x = M._embed_input(params, cfg, {"tokens": toks})
    positions = jnp.arange(toks.shape[1])[None, :]
    # run the stack up to the middle layer to get realistic activations
    half = cfg.n_layers // 2
    for i in range(half):
        block_p = jax.tree.map(lambda p: p[i], params["blocks"])
        x = attention.attn_block_train(block_p, cfg, x, positions,
                                       q_chunk=SEQ, kv_chunk=SEQ)
        from repro.models import layers as L

        hh = L.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
        x = x + L.mlp(block_p["mlp"], hh)
    k, v = capture_layer(params["blocks"], x, positions)
    # [B, S, Hkv, Dh] -> [B*S, Hkv, Dh]
    k = k.reshape(-1, cfg.n_kv_heads, cfg.resolved_head_dim)
    v = v.reshape(-1, cfg.n_kv_heads, cfg.resolved_head_dim)
    return np.asarray(k), np.asarray(v)


class Timer:
    """Median-of-repeats wall timer for jitted callables (CPU)."""

    def __init__(self, warmup: int = 2, repeats: int = 5):
        self.warmup, self.repeats = warmup, repeats

    def us(self, fn, *args) -> float:
        for _ in range(self.warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)
