"""Shared benchmark substrate: one tiny byte-level LM trained on real text,
whose harvested KV tensors drive the accuracy/ratio experiments (the CPU-
scale stand-in for the paper's Llama2/Ministral + CoQA/GSM8K setup — see
DESIGN.md §6 accuracy-proxy note)."""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import TextCorpus
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig

ART = Path(__file__).resolve().parents[1] / "artifacts"
CKPT = ART / "tiny_lm"

TINY = ModelConfig(
    name="tiny-byte-lm", family="dense", n_layers=4, d_model=256,
    vocab_size=256, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
    cache_block=32, rel_scale_k=0.05, rel_scale_v=0.15)

SEQ = 128
STEPS = 300


def get_tiny_lm(steps: int = STEPS, force: bool = False):
    """Train (or load) the tiny LM. Returns (cfg, params, corpus)."""
    data = TextCorpus(seq_len=SEQ, global_batch=8, max_bytes=2 << 20)
    params_shape, _ = step_lib.shapes_and_axes(TINY)
    if not force and store.latest_step(CKPT) is not None:
        params, _ = store.restore(CKPT, params_shape)
        return TINY, params, data
    scfg = step_lib.TrainStepConfig(
        remat=False, q_chunk=SEQ, kv_chunk=SEQ,
        opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps))
    trainer = Trainer(TINY, make_host_mesh(), scfg,
                      TrainerConfig(total_steps=steps, ckpt_every=0,
                                    log_every=50, ckpt_dir=str(CKPT / "_train")),
                      data)
    out = trainer.run()
    print(f"[common] tiny LM trained: {out['final_step']} steps, "
          f"loss {out['last_loss']:.3f}")
    params = jax.tree.map(lambda x: x, trainer.state[0])
    store.save(CKPT, steps, params, {"loss": out["last_loss"]})
    return TINY, params, data


def harvest_kv(cfg, params, data, n_tokens: int = 8192, seed_step: int = 1000):
    """Run the model over text and capture one layer's pre-cache K/V
    ([ctx, heads, head_dim]) — the statistics source for ratio benchmarks."""
    from repro.models import attention

    B = max(1, n_tokens // SEQ)
    batch = data.batch_at(seed_step)
    toks = jnp.asarray(batch["tokens"][:B])

    captured = {}

    def capture_layer(params_blocks, x, positions):
        block_p = jax.tree.map(lambda p: p[cfg.n_layers // 2], params_blocks)
        from repro.models import layers as L

        h = L.rms_norm(x, block_p["ln_attn"], cfg.norm_eps)
        q, k, v = attention.qkv_project(block_p["attn"], cfg, h, positions)
        return k, v

    x = M._embed_input(params, cfg, {"tokens": toks})
    positions = jnp.arange(toks.shape[1])[None, :]
    # run the stack up to the middle layer to get realistic activations
    half = cfg.n_layers // 2
    for i in range(half):
        block_p = jax.tree.map(lambda p: p[i], params["blocks"])
        x = attention.attn_block_train(block_p, cfg, x, positions,
                                       q_chunk=SEQ, kv_chunk=SEQ)
        from repro.models import layers as L

        hh = L.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
        x = x + L.mlp(block_p["mlp"], hh)
    k, v = capture_layer(params["blocks"], x, positions)
    # [B, S, Hkv, Dh] -> [B*S, Hkv, Dh]
    k = k.reshape(-1, cfg.n_kv_heads, cfg.resolved_head_dim)
    v = v.reshape(-1, cfg.n_kv_heads, cfg.resolved_head_dim)
    return np.asarray(k), np.asarray(v)


class Timer:
    """Median-of-repeats wall timer for jitted callables (CPU)."""

    def __init__(self, warmup: int = 2, repeats: int = 5):
        self.warmup, self.repeats = warmup, repeats

    def us(self, fn, *args) -> float:
        for _ in range(self.warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)
