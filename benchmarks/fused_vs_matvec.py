"""Paper Fig. 10 + 11 analogue: fused decompress+matvec vs the plain
(uncompressed) attention matvec — the "beats cuBLAS at long context because
it moves fewer bytes" claim.

On CPU we report measured relative times AND the modeled TPU HBM-traffic
ratio.  Fig. 11's 'equivalent decompression throughput' = raw-cache bytes
divided by the fused kernel's time, normalized by the plain kernel's
bytes/time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cache as C
from repro.kernels import ops

CTX = [2048, 4096, 8192, 16384]
B, Hkv, G, D, T = 4, 4, 2, 64, 64


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(1)
    timer = common.Timer()
    rows = []
    for S in CTX:
        kv = rng.standard_t(4, (2, B, Hkv, S, D)).astype(np.float32)
        k, v = jnp.asarray(kv[0]), jnp.asarray(kv[1])
        q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))

        spec_p = C.CacheSpec(layout="packed", block_size=T, max_seq=S,
                             rel_scale_k=0.05, rel_scale_v=0.15)
        spec_r = dataclasses.replace(spec_p, layout="raw")
        cache_p = C.prefill(spec_p, k, v)
        cache_r = C.prefill(spec_r, k, v)

        fused = jax.jit(lambda c, qq: ops.cache_decode_attention(c, qq, impl="xla"))
        # the plain baseline is the dense uncompressed matvec — the retired
        # materializing attend, NOT the dispatching C.attend (which would
        # route raw through the blockwise backend and measure that instead)
        plain = jax.jit(C.attend_materialized)
        t_fused = timer.us(fused, cache_p, q)
        t_plain = timer.us(plain, cache_r, q)

        # modeled TPU HBM bytes: packed read vs raw bf16 read
        NB = S // T
        packed = (NB * (spec_p.words_k(D) + spec_p.words_v(D)) * 4
                  + NB * (2 * D + 2 * T) * 2) * B * Hkv
        raw = 2 * B * Hkv * S * D * 2
        err = float(jnp.max(jnp.abs(fused(cache_p, q) - plain(cache_r, q))))
        eq_tput_rel = (raw / t_fused) / (raw / t_plain)
        rows.append((
            f"fig10_ctx{S}", t_fused,
            f"plain_us={t_plain:.0f};speedup_cpu={t_plain / t_fused:.2f};"
            f"hbm_packed_MB={packed / 1e6:.1f};hbm_raw_MB={raw / 1e6:.1f};"
            f"hbm_reduction={raw / packed:.2f};"
            f"fig11_eq_decomp_rel={eq_tput_rel:.2f};maxerr={err:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
