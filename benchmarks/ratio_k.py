"""Paper Fig. 7 analogue: K compression ratio vs quantization scale —
KVComp (BlockQuant + Huffman) vs ChannelQuant + Huffman vs KIVI fixed-bit.

Harvested KV from the trained tiny LM provides real language statistics.
The paper's claims to reproduce: +32% avg / +41% max ratio over KIVI at
iso-accuracy, and that BlockQuant's ratio at its turning point beats
ChannelQuant's at its own turning point.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro import api
from repro.core import huffman, quant
from repro.core.codec import huffman_ratio, kivi_ratio
from repro.core.policy import CompressionPolicy, TensorPolicy

# paper Fig. 5 turning points (validated for our model by accuracy_sweep)
BLOCK_SCALES = [0.02, 0.04, 0.05, 0.06, 0.08, 0.12]
CHANNEL_SCALES = [0.1, 0.2, 0.25, 0.3, 0.4]


def _pol(layout: str, rel_k: float) -> CompressionPolicy:
    return CompressionPolicy(layout=layout, block_size=64,
                             k=TensorPolicy(rel_scale=rel_k),
                             v=TensorPolicy(rel_scale=0.15))


def run() -> list[tuple[str, float, str]]:
    cfg, params, data = common.get_tiny_lm()
    k, v = common.harvest_kv(cfg, params, data, n_tokens=8192)
    k, v = jnp.asarray(k), jnp.asarray(v)
    rows = []

    for rel in BLOCK_SCALES:
        # K reports through the facade: the layout objects own the accounting
        r = api.estimate_ratio(k, policy=_pol("huffman", rel), which="k")["k"]
        rp = api.estimate_ratio(k, policy=_pol("packed", rel), which="k")["k"]
        q = quant.quantize_k_block(k, rel, 64)
        err = float(jnp.max(jnp.abs(q.dequantize().reshape(k.shape) - k)))
        rows.append((f"fig7_kvcomp_block_rel{rel}", 0.0,
                     f"ratio={r.ratio:.3f};packed_ratio={rp.ratio:.3f};"
                     f"bits={r.bits_per_value:.3f};maxerr={err:.4f}"))

    for rel in CHANNEL_SCALES:
        q = quant.quantize_k_channel(k, rel)
        book = huffman.build_codebook(np.asarray(huffman.histogram(q.codes)))
        r = huffman_ratio(q, book, (64, k.shape[-1]))
        err = float(jnp.max(jnp.abs(q.dequantize().reshape(k.shape) - k)))
        rows.append((f"fig7_channelquant_rel{rel}", 0.0,
                     f"ratio={r.ratio:.3f};bits={r.bits_per_value:.3f};maxerr={err:.4f}"))

    for bits in (2, 4):
        q = quant.kivi_quantize_k(k, bits, 32)
        r = kivi_ratio(q, bits)
        err = float(jnp.max(jnp.abs(q.dequantize().reshape(k.shape) - k)))
        rows.append((f"fig7_kivi_{bits}bit", 0.0,
                     f"ratio={r.ratio:.3f};bits={r.bits_per_value:.3f};maxerr={err:.4f}"))

    # Headline: iso-accuracy comparison.  Decode-agreement (accuracy_sweep +
    # the calibration in EXPERIMENTS.md §Accuracy) puts KVComp rel=0.05 and
    # KIVI-4bit in the same ~97% agreement band, KIVI-2bit well below it.
    q_ours = quant.quantize_k_block(k, 0.05, 64)
    book = huffman.build_codebook(np.asarray(huffman.histogram(q_ours.codes)))
    r_ours = huffman_ratio(q_ours, book, (64, k.shape[-1]))
    for bits in (4, 2):
        r_kivi = kivi_ratio(quant.kivi_quantize_k(k, bits, 32), bits)
        gain = (r_ours.ratio / r_kivi.ratio - 1) * 100
        rows.append((f"fig7_headline_rel0.05_vs_kivi{bits}", 0.0,
                     f"gain_pct={gain:.1f};iso_accuracy={'yes' if bits == 4 else 'no(kivi2 below band)'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
