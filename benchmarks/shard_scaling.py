"""Shard-scaling capacity bench: serving on a 1/2/4-device mesh at EQUAL
per-device byte budget (DESIGN.md §12).

Each device count N runs the same heterogeneous paged workload through
``api.serve`` on a pure-data ``(N, 1)`` mesh whose pool holds
``N x per_device_budget`` bytes — i.e. every configuration gives each
device the same arena slice, and what scales is how many requests the
fleet admits concurrently plus the aggregate decode rate.  Because the
scheduler pins every row's pages to the row's own data shard, the mesh adds
capacity without changing a single output token (the §12 bit-identity
parity tests assert exactly that).

Device counts are simulated: each N runs in a child process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must be
set before jax initializes, hence the subprocess).

Writes ``BENCH_shard.json``.  ``--require-capacity-win`` exits non-zero
unless the largest mesh admits at least 2x the concurrent requests of the
single device at the same per-device budget (the CI gate).

    PYTHONPATH=src python benchmarks/shard_scaling.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def child_main(args) -> None:
    """One device count: build the mesh, serve the workload, print JSON."""
    import dataclasses

    import jax
    import numpy as np

    from repro import obs
    from repro.core import pool as blockpool
    from repro.launch.mesh import make_serve_mesh
    from repro.models import model as M
    from repro.models import registry
    from repro.serve.scheduler import Request, Server, ServerConfig

    n = args.child
    cfg = dataclasses.replace(registry.get_smoke_config(args.arch),
                              cache_layout=args.layout, cache_block=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = max(4, args.prompt_len
                   - (i * args.prompt_len // 2) // max(args.requests - 1, 1))
        n_new = max(2, args.new_tokens - ((i * 7) % args.new_tokens) // 2)
        reqs.append(Request(prompt=rng.integers(0, cfg.vocab_size,
                                                plen).astype(np.int32),
                            max_new_tokens=n_new))

    specs = M.cache_specs(cfg, args.max_seq)
    page_b = sum(blockpool.page_nbytes(s, cfg.n_kv_heads,
                                       cfg.resolved_head_dim) for s in specs)
    reservation_b = specs[0].n_blocks * page_b
    per_device = args.budget_units * reservation_b
    max_slots = ((args.requests + n - 1) // n) * n
    server = Server(cfg, params,
                    ServerConfig(max_slots=max_slots, max_seq=args.max_seq,
                                 policy="ljf", cache_mode="paged",
                                 pool_hbm_bytes=per_device * n,
                                 mesh=make_serve_mesh(f"{n},1")),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(r) for r in reqs]
    peak = 0
    t0 = time.monotonic()
    while server.step():
        peak = max(peak, server.active)
    wall = time.monotonic() - t0
    toks = sum(len(h.result().tokens) for h in handles)
    st = server.stats()
    out = {
        "devices": n,
        "admitted_peak": peak,
        "tokens": toks,
        "wall_s": wall,
        "tok_s": toks / wall,
        "pool_pages": st["pool"]["pages_total"],
        "pool_high_water_pages": st["pool"]["high_water_pages"],
        "preemptions": st["preemptions"],
        "per_device_budget_bytes": per_device,
        "shards": st["shards"]["per_shard"],
        "metrics": obs.bench_columns(server),
    }
    print(json.dumps(out))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--layout", default="packed")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--budget-units", type=int, default=1,
                    help="per-device pool budget in dense-reservation units")
    ap.add_argument("--device-counts", default="1,2,4")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (short workload)")
    ap.add_argument("--require-capacity-win", action="store_true",
                    help="exit non-zero unless the largest mesh admits >= 2x "
                         "the single device's concurrent requests at equal "
                         "per-device budget (CI gate)")
    ap.add_argument("--out", default="BENCH_shard.json")
    ap.add_argument("--child", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 10)
        args.new_tokens = min(args.new_tokens, 6)
    if args.child:
        child_main(args)
        return

    counts = [int(c) for c in args.device_counts.split(",")]
    bench = {"arch": args.arch, "layout": args.layout,
             "workload": {"requests": args.requests,
                          "prompt_len": args.prompt_len,
                          "new_tokens": args.new_tokens},
             "budget_units_per_device": args.budget_units,
             "counts": {}}
    for n in counts:
        env = dict(os.environ, PYTHONPATH=SRC,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={n}")
        argv = [sys.executable, os.path.abspath(__file__), "--child", str(n),
                "--arch", args.arch, "--layout", args.layout,
                "--requests", str(args.requests),
                "--prompt-len", str(args.prompt_len),
                "--new-tokens", str(args.new_tokens),
                "--max-seq", str(args.max_seq),
                "--budget-units", str(args.budget_units)]
        r = subprocess.run(argv, capture_output=True, text=True, env=env,
                           timeout=900)
        if r.returncode != 0:
            raise SystemExit(
                f"device count {n} failed:\n{r.stderr[-3000:]}")
        res = json.loads(r.stdout.strip().splitlines()[-1])
        bench["counts"][str(n)] = res
        print(f"[mesh {n},1] pool={res['pool_pages']:3d} pages  "
              f"admits {res['admitted_peak']:2d}/{args.requests} "
              f"@ {res['tok_s']:6.1f} tok/s  "
              f"high-water {res['pool_high_water_pages']} "
              f"preempt={res['preemptions']}")

    first, last = bench["counts"][str(counts[0])], bench["counts"][str(counts[-1])]
    bench["capacity_ratio"] = (last["admitted_peak"]
                               / max(first["admitted_peak"], 1))
    bench["tok_s_ratio"] = last["tok_s"] / first["tok_s"]
    # registry-sourced columns for run.py's CSV (largest mesh's run)
    bench["metrics"] = last["metrics"]
    Path(args.out).write_text(json.dumps(bench, indent=2))
    print(f"wrote {args.out}  capacity x{bench['capacity_ratio']:.2f} "
          f"({counts[0]} -> {counts[-1]} devices)")
    if args.require_capacity_win and bench["capacity_ratio"] < 2.0:
        raise SystemExit(
            f"{counts[-1]}-device mesh admitted only "
            f"x{bench['capacity_ratio']:.2f} the single device's concurrent "
            "requests at equal per-device budget (need >= 2x)")


if __name__ == "__main__":
    main()
