"""Decode-path microbench: materializing attend vs blockwise scan vs fused.

The paper's core serving claim is that decompression COST, not ratio,
decides end-to-end decode throughput — the Fetch stage must consume
compressed blocks in situ instead of reconstructing the cache in HBM.  This
bench pins that down for one layer's decode attention across cache layouts
and sequence lengths:

  * ``materialized`` — the retired production path
    (``core.cache.attend_materialized``): dequantize the WHOLE store to a
    ``[B, Hkv, NB, T, D]`` intermediate, one joint softmax.  Survives only
    as this baseline/oracle.
  * ``blockwise``    — the ``"xla"`` backend (``attend_blockwise``): running
    (m, l, acc) scan over the block axis, one lazily-decoded block at a
    time, dequant folded into the matvec.
  * ``fused``        — the ``"fused"`` backend through
    ``kernels.ops.cache_decode_attention`` (Pallas kernel on TPU; its
    vmapped tile-decode oracle elsewhere — the recorded ``impl`` says which
    ran).

Per cell it reports attention steps/s → tok/s (steps × batch / wall) and the
compiled peak temp memory (``memory_analysis().temp_size_in_bytes`` — the
materialized intermediate shows up here).  Writes ``BENCH_decode.json``;
``--require-win`` gates CI on (a) the production path (blockwise off-TPU)
matching or beating the materializing baseline per decode-cheap layout in
geomean, and (b) the huffman FUSED leg existing (``supports_fused`` — the
maximal-ratio layout must serve through the fused backend, DESIGN.md §9)
and staying within ``FUSED_GATE_MIN`` of huffman-blockwise at the longest
context, with one remeasure before failing.  The band, not strict >= 1.0:
on idle hardware the fused leg wins the long-context cell (x1.2-1.4
recorded in BENCH_decode.json; the pre-LUT deficit was x0.95 with decode
~10x slower overall), but the CPU oracle's wide one-pass decode is
bimodal under box state (+-2x observed), while a genuine decode
regression — say the one-tree-step-per-BIT walk sneaking back — lands far
below the band.  The definitive fused-vs-blockwise numbers are the
real-TPU bench pass's to claim (ROADMAP).  Huffman's (a) is reported but
not gated for the same variance reason: its two one-pass decode paths
(materialized, fused-CPU-oracle) and the span-chunked scan trade places
with context length and box load.

    PYTHONPATH=src python benchmarks/decode_path.py --smoke --require-win
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as C
from repro.kernels import ops
from repro.kernels.runtime import on_tpu


def build_cache(rng, layout: str, B: int, Hkv: int, D: int, S: int,
                block: int) -> C.LayerKVCache:
    spec = C.CacheSpec(layout=layout, block_size=block, max_seq=S)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    return C.prefill(spec, k, v)


def peak_temp_bytes(fn, *args) -> int | None:
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(ma.temp_size_in_bytes)
    except Exception:
        return None  # backends without memory_analysis support


def bench_paths(fns: dict, cache, q, steps: int, repeats: int) -> dict:
    """Measure all paths with interleaved repeats (every repeat times each
    path back to back, so host-speed drift hits them equally) and take the
    per-path median wall."""
    jfns = {n: jax.jit(fn) for n, fn in fns.items()}
    for jfn in jfns.values():
        jfn(cache, q).block_until_ready()  # compile + warmup
    walls = {n: [] for n in fns}
    for _ in range(repeats):
        for n, jfn in jfns.items():
            t0 = time.monotonic()
            for _ in range(steps):
                out = jfn(cache, q)
            out.block_until_ready()
            walls[n].append(time.monotonic() - t0)
    B = q.shape[0]
    out = {}
    for n, ws in walls.items():
        wall = sorted(ws)[len(ws) // 2]
        out[n] = {"wall_s": wall, "steps": steps, "tok_s": steps * B / wall,
                  "peak_temp_bytes": peak_temp_bytes(fns[n], cache, q)}
    return out


PATHS = {
    "materialized": lambda c, q: C.attend_materialized(c, q),
    "blockwise": lambda c, q: C.attend_blockwise(c, q),
    "fused": lambda c, q: ops.cache_decode_attention(c, q),
}

# --require-win floor for huffman fused-vs-blockwise at the longest context
# (see module docstring: wins on idle hardware, band absorbs the recorded
# +-2x box-state bimodality of the CPU oracle's one-pass decode).
FUSED_GATE_MIN = 0.6


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layouts", default="raw,packed,kivi,huffman")
    ap.add_argument("--seq-lens", default="1024,4096")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--gqa", type=int, default=4)
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (compressed layouts, short run)")
    ap.add_argument("--require-win", action="store_true",
                    help="exit non-zero unless, per decode-cheap layout, the "
                         "production path (blockwise off-TPU, fused on TPU) "
                         ">= the materializing baseline tok/s in geomean "
                         "over the seq-len grid, AND huffman serves a fused "
                         "leg within FUSED_GATE_MIN of blockwise at the "
                         "longest context (see module docstring)")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args()
    if args.smoke:
        # CI gate runs the production layout of the paper's TPU path plus
        # the maximal-ratio huffman layout (its fused-vs-blockwise win is
        # gated); the full grid (default args) additionally reports raw/kivi.
        args.layouts = "packed,huffman"
        args.seq_lens = "1024,4096"
        args.steps = 5

    production = "fused" if on_tpu() else "blockwise"
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(
        size=(args.batch, args.kv_heads * args.gqa, args.head_dim)
        ).astype(np.float32))

    bench = {"batch": args.batch, "kv_heads": args.kv_heads,
             "head_dim": args.head_dim, "gqa": args.gqa, "block": args.block,
             "production_path": production,
             "fused_impl": ops.resolve_impl("auto"), "cells": []}
    speedups: dict[str, list[float]] = {}
    fused_ratios: dict[str, list[float]] = {}
    for layout in args.layouts.split(","):
        for S in (int(s) for s in args.seq_lens.split(",")):
            cache = build_cache(rng, layout, args.batch, args.kv_heads,
                                args.head_dim, S, args.block)
            fns = {n: f for n, f in PATHS.items()
                   if n != "fused" or cache.spec.impl.supports_fused}
            cell = {"layout": layout, "seq_len": S,
                    "paths": bench_paths(fns, cache, q, args.steps,
                                         args.repeats)}
            prod = cell["paths"].get(production) or cell["paths"]["blockwise"]
            base = cell["paths"]["materialized"]
            cell["production_speedup"] = prod["tok_s"] / base["tok_s"]
            mem = (base["peak_temp_bytes"] / prod["peak_temp_bytes"]
                   if prod["peak_temp_bytes"] else None)
            cell["production_mem_reduction"] = mem
            if "fused" in cell["paths"]:
                cell["fused_vs_blockwise"] = (
                    cell["paths"]["fused"]["tok_s"]
                    / cell["paths"]["blockwise"]["tok_s"])
                fused_ratios.setdefault(layout, []).append(
                    cell["fused_vs_blockwise"])
            bench["cells"].append(cell)
            speedups.setdefault(layout, []).append(cell["production_speedup"])
            print(f"[{layout:8s} S={S:5d}] " + "  ".join(
                f"{n} {p['tok_s']:9.1f} tok/s"
                + (f" temp {p['peak_temp_bytes']:>11,}B"
                   if p["peak_temp_bytes"] is not None else "")
                for n, p in cell["paths"].items())
                + f"  prod x{cell['production_speedup']:.2f}")

    geomean = lambda xs: float(np.exp(np.mean(np.log(xs))))
    bench["layout_geomean_speedup"] = {
        l: geomean(xs) for l, xs in speedups.items()}
    bench["layout_geomean_fused_vs_blockwise"] = {
        l: geomean(xs) for l, xs in fused_ratios.items()}
    Path(args.out).write_text(json.dumps(bench, indent=2))
    print("per-layout geomean production speedup: " + "  ".join(
        f"{l} x{x:.2f}" for l, x in bench["layout_geomean_speedup"].items()))
    print("per-layout geomean fused-vs-blockwise: " + "  ".join(
        f"{l} x{x:.2f}"
        for l, x in bench["layout_geomean_fused_vs_blockwise"].items()))
    print(f"wrote {args.out}")
    if args.require_win:
        losses = {l: x for l, x in bench["layout_geomean_speedup"].items()
                  if x < 1.0 and l != "huffman"}  # see module docstring (b)
        if losses:
            raise SystemExit(
                "production decode path lost to the materializing baseline on: "
                + ", ".join(f"{l} ({x:.2f}x)" for l, x in losses.items()))
        # The maximal-ratio layout must serve through the fused backend
        # (before PR 5 it silently fell back to the blockwise scan) and its
        # in-kernel decode must stay in the same league as blockwise at
        # long context — see module docstring for the FUSED_GATE_MIN band.
        hf_all = [c for c in bench["cells"] if c["layout"] == "huffman"]
        hf_cells = [c for c in hf_all if "fused_vs_blockwise" in c]
        if hf_all and not hf_cells:
            raise SystemExit(
                "huffman has no fused leg: the layout lost supports_fused")
        if hf_cells:
            longest = max(hf_cells, key=lambda c: c["seq_len"])
            S, ratio = longest["seq_len"], longest["fused_vs_blockwise"]
            if ratio < FUSED_GATE_MIN:
                # Transient-load guard: the decisive ratio is a wall-clock
                # measurement; remeasure the one cell before failing, so a
                # loaded runner doesn't red the pipeline while a real
                # regression still fails twice.
                cache = build_cache(rng, "huffman", args.batch, args.kv_heads,
                                    args.head_dim, S, args.block)
                paths = bench_paths(
                    {n: PATHS[n] for n in ("blockwise", "fused")},
                    cache, q, args.steps, args.repeats)
                retry = paths["fused"]["tok_s"] / paths["blockwise"]["tok_s"]
                print(f"huffman-fused gate retry at S={S}: x{retry:.2f} "
                      f"(first run x{ratio:.2f})")
                ratio = max(ratio, retry)
            if ratio < FUSED_GATE_MIN:
                raise SystemExit(
                    f"huffman-fused fell below x{FUSED_GATE_MIN} of "
                    f"huffman-blockwise at S={S} ({ratio:.2f}x, twice)")


if __name__ == "__main__":
    main()
