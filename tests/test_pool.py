"""Paged compressed-block pool (DESIGN.md §10).

Four layers of guarantees:

* allocator invariants — alloc/free never double-assign a page, occupancy
  equals live pages x post-compression page bytes, page tables never alias
  across rows (hypothesis property tests);
* storage parity — every decode path (blockwise scan, fused oracle, fused
  Pallas kernel, materializing oracle) reads identical attention out of
  paged arenas and dense rings, including appends, heterogeneous rows, and
  sliding-window ring reuse;
* serving semantics — memory-pressure admission oversubscribes slots past
  the dense reservation, and a forced preemption + prompt replay leaves
  greedy tokens bit-identical to solo decode for raw, packed, and kivi;
* scheduler hygiene — the ljf pop is a direct index scan whose tie-break
  preserves arrival order, and CacheSpec rejects windows the block ring
  cannot represent.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C
from repro.core import pool
from repro.core.policy import CompressionPolicy, LayerOverride
from repro.kernels import ops
from repro.models import model as M
from repro.models import registry
from repro.models.config import ModelConfig
from repro.serve.scheduler import Request, Server, ServerConfig


# ---------------------------------------------------------------------------
# CacheSpec / policy validation (satellites)
# ---------------------------------------------------------------------------


def test_cachespec_rejects_window_not_divisible_by_block():
    with pytest.raises(ValueError, match="must divide window"):
        C.CacheSpec(block_size=16, window=40, max_seq=256)
    # regression: divisible windows (and window=None) are untouched
    assert C.CacheSpec(block_size=16, window=32, max_seq=256).n_blocks == 2
    assert C.CacheSpec(block_size=16, max_seq=256).window is None


def test_cachespec_paged_validation():
    with pytest.raises(ValueError, match="pool_pages"):
        C.CacheSpec(mode="paged")
    with pytest.raises(ValueError, match="mode must be"):
        C.CacheSpec(mode="vram")
    spec = C.CacheSpec(mode="paged", pool_pages=12, block_size=16, max_seq=64)
    assert spec.paged and spec.store_blocks == 12 and spec.n_blocks == 4


def test_policy_mode_threads_to_spec_and_dense_twin():
    pol = CompressionPolicy(layout="packed", mode="paged", block_size=16)
    # without a sized pool every consumer gets the dense twin (solo
    # prefills, api.compress, dryrun)
    assert pol.spec_for_layer(0, max_seq=64).mode == "dense"
    spec = pol.spec_for_layer(0, max_seq=64, pool_pages=9)
    assert spec.mode == "paged" and spec.pool_pages == 9
    with pytest.raises(ValueError, match="uniform block_size"):
        CompressionPolicy(mode="paged",
                          overrides=(LayerOverride(layers=(1,), block_size=32),))
    with pytest.raises(ValueError, match="mode must be"):
        CompressionPolicy(mode="hbm")


def test_model_config_cache_mode_threads():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      vocab_size=64, n_heads=2, n_kv_heads=2,
                      cache_mode="paged", cache_block=16)
    assert cfg.compression_policy().mode == "paged"
    assert M.cache_spec(cfg, 64).mode == "dense"  # dense twin without a pool
    assert M.cache_spec(cfg, 64, pool_pages=7).pool_pages == 7


# ---------------------------------------------------------------------------
# Allocator invariants
# ---------------------------------------------------------------------------


def test_pool_alloc_free_basics():
    p = pool.PagedBlockPool(4, (100, 20))
    a = p.alloc(3)
    assert len(set(a)) == 3 and p.free_pages == 1
    assert p.live_bytes == 3 * 120 and p.total_bytes == 4 * 120
    with pytest.raises(pool.PoolExhausted):
        p.alloc(2)
    assert p.free_pages == 1  # failed alloc takes nothing
    assert p.release(a[:1]) == [a[0]]
    assert p.free_pages == 2 and p.high_water == 3
    with pytest.raises(RuntimeError, match="not live"):
        p.release(a[:1])  # double release
    with pytest.raises(RuntimeError, match="not live"):
        p.release([99])  # never allocated
    with pytest.raises(RuntimeError, match="not live"):
        p.retain([99])  # can't retain a dead page either


def test_pool_property_invariants(rng):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 5)), max_size=60))
    def run(ops_):
        p = pool.PagedBlockPool(12, (64,))
        held: list[int] = []
        for is_alloc, n in ops_:
            if is_alloc:
                if n <= p.free_pages:
                    got = p.alloc(n)
                    # never double-assign: fresh pages disjoint from held
                    assert not (set(got) & set(held))
                    held += got
                else:
                    with pytest.raises(pool.PoolExhausted):
                        p.alloc(n)
            elif held:
                k = min(n, len(held))
                assert sorted(p.release(held[:k])) == sorted(held[:k])
                held = held[k:]
            # occupancy == sum of live page bytes, conservation holds
            assert p.live_pages == len(held) == len(set(held))
            assert p.live_bytes == len(held) * 64
            assert p.free_pages + p.live_pages == p.n_pages

    run()


def test_page_tables_never_alias_across_rows():
    """Scheduler-shaped workload on the allocator + a page table mirror:
    whatever interleaving of admissions, per-step assignments, and releases
    happens, no two (row, slot) entries may ever share a physical page."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    B, NB = 4, 8

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, B - 1), st.integers(0, NB - 1),
                              st.integers(0, 2)), max_size=80))
    def run(events):
        p = pool.PagedBlockPool(10, (32,))
        table = np.full((B, NB), -1)
        for row, slot, kind in events:
            if kind == 2:  # release the row (retire / preempt)
                held = table[row][table[row] >= 0]
                if len(held):
                    p.release(held.tolist())
                table[row] = -1
            elif table[row, slot] < 0 and p.free_pages:
                table[row, slot] = p.alloc(1)[0]
            live = table[table >= 0]
            assert len(live) == len(set(live.tolist()))  # no aliasing
            assert set(live.tolist()) == p._live
            assert p.live_bytes == len(live) * 32

    run()


def test_page_nbytes_tracks_compression():
    """The admission unit is post-compression bytes: a packed page must be
    far smaller than a raw page of the same block, and differencing the
    layout's own store shapes must match a hand count for packed."""
    mk = lambda layout: C.CacheSpec(layout=layout, block_size=16, max_seq=64,
                                    rel_scale_k=0.05, rel_scale_v=0.15)
    H, D = 2, 16
    raw_b = pool.page_nbytes(mk("raw"), H, D)
    packed_b = pool.page_nbytes(mk("packed"), H, D)
    assert raw_b == 2 * H * 16 * D * 2  # K+V bf16 blocks
    assert packed_b < raw_b / 2
    spec = mk("packed")
    expect = H * 4 * (spec.words_k(D) + spec.words_v(D))  # u32 payload
    expect += H * 2 * 2 * (D + spec.block_size)           # bf16 min/step K+V
    assert packed_b == expect


# ---------------------------------------------------------------------------
# Storage parity: paged arenas vs dense rings on every decode path
# ---------------------------------------------------------------------------


def _mk_kvq(rng, B, Hkv, G, S, D):
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    return k, v, q


def _paged_outputs(cache, q):
    outs = {
        "blockwise": C.attend_blockwise(cache, q),
        "materialized": C.attend_materialized(cache, q),
    }
    if cache.spec.impl.supports_fused:
        outs["fused_oracle"] = ops.cache_decode_attention(cache, q, impl="xla")
        outs["fused_pallas"] = ops.cache_decode_attention(cache, q, impl="pallas")
    return outs


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi", "huffman"])
def test_paged_parity_all_backends(layout, rng):
    """A dense cache re-housed under a shuffled page assignment must attend
    identically on every backend (the paged parity suite)."""
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=128,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    k, v, q = _mk_kvq(rng, 2, 2, 2, 72, 16)
    dense = C.prefill(spec, k, v)
    B, NB = 2, spec.n_blocks
    perm = rng.permutation(B * NB + 3)[: B * NB].reshape(B, NB).astype(np.int32)
    paged = pool.from_dense(dense, B * NB + 3, perm)
    assert paged.spec.paged and paged.k_store.shape[0] == 1
    ref = C.attend_blockwise(dense, q)
    for name, out in _paged_outputs(paged, q).items():
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-3, err_msg=name)
    # the blockwise path reads identical bits through the indirection
    np.testing.assert_array_equal(
        np.asarray(C.attend_blockwise(paged, q)), np.asarray(ref))


def test_paged_parity_heterogeneous_rows(rng):
    """Per-row nb_valid/buf_len + per-row page tables: rows at different
    positions must match their dense twins bit-for-bit per backend."""
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=256)
    k, v, q = _mk_kvq(rng, 2, 2, 2, 96, 16)
    c40 = C.prefill(spec, k[:, :, :40], v[:, :, :40])
    c96 = C.prefill(spec, k, v)
    mixed = jax.tree.map(lambda a, b: jnp.stack([a[0], b[1]]), c40, c96)
    NB = spec.n_blocks
    perm = rng.permutation(2 * NB).reshape(2, NB).astype(np.int32)
    paged = pool.from_dense(mixed, 2 * NB, perm)
    dense_outs = {
        "blockwise": C.attend_blockwise(mixed, q),
        "materialized": C.attend_materialized(mixed, q),
        "fused_oracle": ops.cache_decode_attention(mixed, q, impl="xla"),
        "fused_pallas": ops.cache_decode_attention(mixed, q, impl="pallas"),
    }
    for name, out in _paged_outputs(paged, q).items():
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(dense_outs[name]), err_msg=name)


@pytest.mark.parametrize("layout", ["raw", "packed"])
def test_paged_append_and_ring_reuse(layout, rng):
    """Decode-time flushes translate through the page table; a sliding
    window wraps its ring by overwriting the slot's page IN PLACE (no new
    allocation), staying bit-identical to the dense ring."""
    spec = C.CacheSpec(layout=layout, block_size=8, max_seq=512, window=32,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    k, v, q = _mk_kvq(rng, 2, 2, 2, 20, 16)
    dense = C.prefill(spec, k, v)
    paged = pool.from_dense(dense, 2 * spec.n_blocks)
    tab_before = np.asarray(paged.page_tab).copy()
    app = jax.jit(C.append)
    for t in range(40):
        kn = jnp.asarray(rng.normal(size=(2, 2, 16)).astype(np.float32))
        vn = jnp.asarray(rng.normal(size=(2, 2, 16)).astype(np.float32))
        dense = app(dense, kn, vn)
        paged = app(paged, kn, vn)
    assert int(dense.n_flushed[0]) > spec.n_blocks  # the ring wrapped
    np.testing.assert_array_equal(np.asarray(paged.page_tab), tab_before)
    np.testing.assert_array_equal(np.asarray(C.attend_blockwise(paged, q)),
                                  np.asarray(C.attend_blockwise(dense, q)))


def test_paged_prefill_rejected_and_to_dense_roundtrip(rng):
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                       mode="paged", pool_pages=8)
    with pytest.raises(ValueError, match="dense twin|from_dense"):
        C.prefill(spec, *(_mk_kvq(rng, 1, 2, 1, 40, 16)[:2]))
    dspec = dataclasses.replace(spec, mode="dense", pool_pages=0)
    k, v, q = _mk_kvq(rng, 1, 2, 1, 40, 16)
    dense = C.prefill(dspec, k, v)
    back = pool.to_dense(pool.from_dense(dense, 8))
    assert not back.spec.paged
    np.testing.assert_array_equal(np.asarray(back.k_store)[:, :, :2],
                                  np.asarray(dense.k_store)[:, :, :2])


# ---------------------------------------------------------------------------
# Serving: admission, oversubscription, preemption (model-backed)
# ---------------------------------------------------------------------------

LENS = (7, 13, 16, 24, 33)
NEWS = (3, 9, 5, 2, 7)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("yi_6b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32) for L in LENS]
    return cfg, params, prompts


def _solo_greedy(cfg, params, prompt, n_new):
    # B=1 block-chunked prefill (the unified admission semantics: chunks
    # attend earlier blocks compressed, as decode will) + greedy decode.
    prompt = np.asarray(prompt, np.int32)
    T = M.cache_specs(cfg, 256)[0].block_size
    state = M.init_decode_state(cfg, 1, 256)
    lg, pos = None, 0
    while pos < len(prompt):
        C = min(T, len(prompt) - pos)
        lg, state = M.prefill_chunk(params, cfg,
                                    jnp.asarray(prompt[None, pos:pos + C]),
                                    jnp.int32(pos), state)
        pos += C
    cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    out = [int(cur[0])]
    while len(out) < n_new:
        lg, state = M.decode_step(params, cfg, cur,
                                  jnp.asarray(pos, jnp.int32), state)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out.append(int(cur[0]))
        pos += 1
    return out


def _pool_page_bytes(cfg, max_seq=256):
    specs = M.cache_specs(cfg, max_seq)
    return sum(pool.page_nbytes(s, cfg.n_kv_heads, cfg.resolved_head_dim)
               for s in specs), specs[0]


@pytest.mark.parametrize("layout", ["raw", "packed"])
def test_paged_server_mid_flight_matches_solo(setup, layout):
    """The scheduler suite's core contract on paged storage: mixed prompt
    lengths/budgets through few slots, every request bit-identical to its
    solo run, pool fully drained at the end."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout=layout, cache_block=8)
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=256, cache_mode="paged"),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    server.run()
    for p, n, h in zip(prompts, NEWS, handles):
        assert h.result().tokens.tolist() == _solo_greedy(cfg, params, p, n), \
            (layout, len(p), n)
    st = server.stats()
    assert st["pool"]["pages_live"] == 0  # every retirement freed its pages
    assert st["pool"]["bytes_live"] == 0


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi"])
def test_preempt_and_resume_bit_identity(setup, layout):
    """A pool too small for the admitted load forces a preemption; the
    preempted request replays its prompt on re-admission and its greedy
    tokens stay bit-identical to a solo run (the acceptance contract)."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout=layout, cache_block=8)
    page_b, spec0 = _pool_page_bytes(cfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
               for _ in range(3)]
    # 5 pages: two requests admit (2 prefill pages + headroom each), their
    # decode flushes exhaust the pool, the youngest gets preempted.
    server = Server(cfg, params,
                    ServerConfig(max_slots=3, max_seq=256, cache_mode="paged",
                                 pool_hbm_bytes=5 * page_b),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=10))
               for p in prompts]
    server.run()
    assert server.preemptions >= 1, "workload failed to force a preemption"
    for p, h in zip(prompts, handles):
        assert h.result().tokens.tolist() == _solo_greedy(cfg, params, p, 10)
    assert server.stats()["pool"]["pages_live"] == 0


def test_streaming_survives_preemption(setup):
    """handle.tokens() across a preemption: the regenerated prefix is
    identical, so the stream continues seamlessly."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    page_b, _ = _pool_page_bytes(cfg)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
               for _ in range(3)]
    server = Server(cfg, params,
                    ServerConfig(max_slots=3, max_seq=256, cache_mode="paged",
                                 pool_hbm_bytes=5 * page_b),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=10))
               for p in prompts]
    streamed = [list(h.tokens()) for h in handles]
    assert server.preemptions >= 1
    for s, h in zip(streamed, handles):
        assert s == h.result().tokens.tolist() and len(s) == 10


def _device_page_tables(server):
    """Every layer's device page table as a host [B, NB] array."""
    kv = server.state["kv"]
    caches = kv if isinstance(kv, (tuple, list)) else (kv,)
    tabs = []
    for c in caches:
        pt = np.asarray(c.page_tab)
        tabs.extend(pt if pt.ndim == 3 else [pt])  # layer-stacked or single
    return tabs


def test_same_sweep_preemption_drops_stale_page_assignment(setup):
    """Regression: a row granted a page early in an ``_ensure_pages`` sweep
    can be preempted LATER in the same sweep — a younger zero-page row
    exhausts the pool and the victim scan picks the youngest page HOLDER,
    which is the older, already-recorded row.  Its freed page is re-issued
    (LIFO) to the younger row; the stale triple must not re-point the
    cleared device row at it, or the vacated slot's garbage flush lands in
    the other request's live page this very step."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    page_b, _ = _pool_page_bytes(cfg)
    rng = np.random.default_rng(17)
    pa = rng.integers(0, cfg.vocab_size, 14).astype(np.int32)  # 1 prefill page
    pb = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)   # 0 prefill pages
    # 2 pages: both admit together (A takes one for its prompt block), and
    # one decode step later both hit a flush boundary in the SAME sweep
    # (pos 15 and 7).  A — older, visited first — takes the last free page;
    # B holds zero pages, so the victim scan preempts A and the LIFO free
    # list hands B the exact page A was just granted.
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=256, cache_mode="paged",
                                 pool_hbm_bytes=2 * page_b),
                    q_chunk=32, kv_chunk=32)
    ha = server.submit(Request(prompt=pa, max_new_tokens=6))
    hb = server.submit(Request(prompt=pb, max_new_tokens=6))
    while server.step():
        # the device tables must mirror the host accounting at every step:
        # under the bug, A's cleared device row resurrects with the stale
        # (row, slot, page) triple pointing into B's page
        for tab in _device_page_tables(server):
            np.testing.assert_array_equal(tab, server._pt_host)
    assert server.preemptions >= 1, "workload failed to force the same-sweep case"
    assert ha.result().tokens.tolist() == _solo_greedy(cfg, params, pa, 6)
    assert hb.result().tokens.tolist() == _solo_greedy(cfg, params, pb, 6)
    assert server.stats()["pool"]["pages_live"] == 0


def test_paged_admits_more_than_dense_at_same_budget(setup):
    """The capacity claim: at one fixed byte budget, paged admission holds
    >= 1.5x the concurrent requests of dense full-ring reservation for a
    compressing layout."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    page_b, spec0 = _pool_page_bytes(cfg)
    budget = 2 * spec0.n_blocks * page_b  # exactly two dense reservations
    dense_slots = budget // (spec0.n_blocks * page_b)
    assert dense_slots == 2
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 17).astype(np.int32),
                    max_new_tokens=8) for _ in range(8)]
    server = Server(cfg, params,
                    ServerConfig(max_slots=len(reqs), max_seq=256,
                                 cache_mode="paged", pool_hbm_bytes=budget),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(r) for r in reqs]
    peak = 0
    while server.step():
        peak = max(peak, server.active)
    assert peak >= 1.5 * dense_slots, (peak, dense_slots)
    for r, h in zip(reqs, handles):
        assert h.result().tokens.tolist() == _solo_greedy(
            cfg, params, r.prompt, r.max_new_tokens)


def test_submit_rejects_request_larger_than_pool(setup):
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    page_b, _ = _pool_page_bytes(cfg)
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=256, cache_mode="paged",
                                 pool_hbm_bytes=3 * page_b),
                    q_chunk=32, kv_chunk=32)
    with pytest.raises(ValueError, match="pool"):
        server.submit(Request(prompt=np.zeros(64, np.int32), max_new_tokens=32))
    # a request that fits the pool is accepted
    server.submit(Request(prompt=np.zeros(9, np.int32), max_new_tokens=4))
    # exact block boundary: the final generated token is never appended, so
    # prompt + max_new = 32 peaks at 31 entries = 3 pages, filling the pool
    # exactly — admissible solo (the old off-by-one rejected it)
    server.submit(Request(prompt=np.zeros(25, np.int32), max_new_tokens=7))


def test_server_stats_shape(setup):
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=256, cache_mode="paged"),
                    q_chunk=32, kv_chunk=32)
    st = server.stats()
    assert st["cache_mode"] == "paged" and st["preemptions"] == 0
    pl = st["pool"]
    assert pl["pages_free"] == pl["pages_total"] and pl["bytes_live"] == 0
    assert pl["bytes_total"] == pl["pages_total"] * pl["bytes_per_page"]
    assert len(pl["bytes_live_by_layer"]) == cfg.n_layers
    dense = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                   q_chunk=32, kv_chunk=32)
    assert dense.stats()["cache_mode"] == "dense"
    assert "pool" not in dense.stats()


# ---------------------------------------------------------------------------
# Scheduler hygiene: the ljf pop (satellite)
# ---------------------------------------------------------------------------


def test_pop_next_ljf_tie_break_preserves_arrival_order(setup):
    cfg, params, _ = setup
    server = Server(cfg, params,
                    ServerConfig(max_slots=1, max_seq=256, policy="ljf"),
                    q_chunk=32, kv_chunk=32)
    budgets = [3, 5, 2, 5, 5, 4]
    handles = [server.submit(Request(prompt=np.zeros(4, np.int32),
                                     max_new_tokens=b)) for b in budgets]
    order = [server._pop_next() for _ in range(len(budgets))]
    # max budget first; equal budgets leave in arrival order; rest follow
    assert [h.request.max_new_tokens for h in order] == [5, 5, 5, 4, 3, 2]
    assert order[0] is handles[1] and order[1] is handles[3]
    assert order[2] is handles[4]
    assert not server._queue


def test_pop_next_fcfs_is_fifo(setup):
    cfg, params, _ = setup
    server = Server(cfg, params, ServerConfig(max_slots=1, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=np.zeros(4, np.int32),
                                     max_new_tokens=b)) for b in (2, 9, 3)]
    assert [server._pop_next() for _ in range(3)] == handles


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi", "huffman"])
def test_chunked_vs_solo_admission_bit_identity_paged(setup, layout):
    """Bit-identity matrix, paged leg: the fused encode-to-page chunk loop
    (chunks quantize straight into pooled pages through a live-arena view)
    must match the blocking solo drain token for token on every layout."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout=layout, cache_block=8)
    outs = {}
    for mode in ("chunked", "solo"):
        server = Server(cfg, params,
                        ServerConfig(max_slots=2, max_seq=256,
                                     cache_mode="paged", prefill_mode=mode,
                                     prefill_chunk_tokens=8),
                        q_chunk=32, kv_chunk=32)
        hs = [server.submit(Request(prompt=p, max_new_tokens=n))
              for p, n in zip(prompts[:3], NEWS[:3])]
        server.run()
        outs[mode] = [h.result().tokens.tolist() for h in hs]
        st = server.stats()
        assert st["prefill"]["mode"] == mode
        assert st["pool"]["pages_live"] == 0  # drained either way
    assert outs["chunked"] == outs["solo"]


def test_preempt_half_prefilled_row_resumes(setup):
    """A PREFILLING row can lose its pages mid-chunking: an older decoder
    holds part of a pool the long prompt needs, the chunk loop's page
    reclaim preempts the (younger) half-prefilled row itself, and its
    re-admission must still produce solo-identical tokens."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    page_b, _ = _pool_page_bytes(cfg)
    rng = np.random.default_rng(23)
    short = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)  # 5 blocks
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=64, cache_mode="paged",
                                 pool_hbm_bytes=5 * page_b,
                                 prefill_chunk_tokens=8),
                    q_chunk=32, kv_chunk=32)
    h_short = server.submit(Request(prompt=short, max_new_tokens=16))
    server.step()  # the short decoder admits first (it is the OLDER row)
    h_long = server.submit(Request(prompt=long, max_new_tokens=4))
    server.run()
    pf = server.stats()["prefill"]
    assert pf["prefill_preemptions"] >= 1, \
        "workload failed to preempt a half-prefilled row"
    assert h_short.result().tokens.tolist() == _solo_greedy(cfg, params,
                                                            short, 16)
    assert h_long.result().tokens.tolist() == _solo_greedy(cfg, params,
                                                           long, 4)
    assert server.stats()["pool"]["pages_live"] == 0
