"""Mamba2 SSD: chunked scan vs sequential oracle; decode-chain equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.models import ssm
from repro.models.config import ModelConfig


def _inputs(rng, b, S, H, P, G, N):
    x = jnp.asarray(rng.normal(size=(b, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.05, 0.9, (b, S, H)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.3, 2.5, (H,)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(b, S, G, N)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, S, G, N)).astype(np.float32))
    return x, dt * A[None, None], dt, B, C


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([4, 8, 16, 48]),
       S=st.sampled_from([16, 48]))
def test_ssd_scan_matches_reference(seed, chunk, S):
    rng = np.random.default_rng(seed)
    x, a, dt, B, C = _inputs(rng, 2, S, 4, 8, 2, 16)
    y1, h1 = ssm.ssd_scan(x, a, dt, B, C, chunk)
    y2, h2 = ssm.ssd_reference(x, a, dt, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-3)


def test_ssd_scan_chunk_invariance(rng):
    x, a, dt, B, C = _inputs(rng, 1, 32, 2, 4, 1, 8)
    outs = [np.asarray(ssm.ssd_scan(x, a, dt, B, C, c)[0]) for c in (4, 8, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-4)


def test_ssd_initial_state_carries(rng):
    """Running [first half] then [second half with h0] == full run."""
    x, a, dt, B, C = _inputs(rng, 1, 32, 2, 4, 1, 8)
    y_full, h_full = ssm.ssd_scan(x, a, dt, B, C, 8)
    y1, h1 = ssm.ssd_scan(x[:, :16], a[:, :16], dt[:, :16], B[:, :16], C[:, :16], 8)
    y2, h2 = ssm.ssd_scan(x[:, 16:], a[:, 16:], dt[:, 16:], B[:, 16:], C[:, 16:], 8, h0=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 16:]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)


CFG = ModelConfig(name="m", family="ssm", n_layers=1, d_model=32, vocab_size=64,
                  ssm_state=16, ssm_head_dim=8, ssm_expand=2, ssm_groups=2,
                  ssm_chunk=16)


def test_block_prefill_equals_train(rng):
    params, _ = ssm.init_mamba_block(jax.random.PRNGKey(0), CFG)
    u = jnp.asarray(rng.normal(size=(2, 48, 32)).astype(np.float32))
    out_t = ssm.mamba_block_train(params, CFG, u)
    out_p, state = ssm.mamba_block_prefill(params, CFG, u)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_p), atol=1e-5)


def test_block_decode_chain_matches_full(rng):
    params, _ = ssm.init_mamba_block(jax.random.PRNGKey(0), CFG)
    u = jnp.asarray(rng.normal(size=(2, 48, 32)).astype(np.float32))
    u2 = jnp.asarray(rng.normal(size=(2, 6, 32)).astype(np.float32))
    _, state = ssm.mamba_block_prefill(params, CFG, u)
    outs = []
    for t in range(6):
        o, state = ssm.mamba_block_decode(params, CFG, u2[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    full = ssm.mamba_block_train(params, CFG, jnp.concatenate([u, u2], axis=1))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 48:]),
                               atol=1e-4, rtol=1e-3)


def test_decode_from_empty_state(rng):
    """Decode-only from init state == training forward over those tokens."""
    params, _ = ssm.init_mamba_block(jax.random.PRNGKey(0), CFG)
    u = jnp.asarray(rng.normal(size=(1, 5, 32)).astype(np.float32))
    state = ssm.init_mamba_state(CFG, 1)
    outs = []
    for t in range(5):
        o, state = ssm.mamba_block_decode(params, CFG, u[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    full = ssm.mamba_block_train(params, CFG, u)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4,
                               rtol=1e-3)
