"""Logical-axis sharding rules: conflict sanitation, divisibility fallback,
per-family rule tables, cache shardings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def amesh(shape, names):
    return shd.abstract_mesh(shape, names)
from repro.models import registry
from repro.train import step as step_lib


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh: spec construction needs only axis names/sizes
    return amesh((1, 1), ("data", "model"))


def test_spec_conflict_sanitation(mesh):
    rules = shd.Rules({"x": "model", "y": "model"})
    spec = shd.spec_for_axes(("x", "y"), (16, 16), rules, mesh)
    # second use of "model" must be dropped
    assert spec == P("model") or spec == P("model", None)


def test_spec_divisibility_fallback():
    mesh = amesh((1, 1), ("data", "model"))
    rules = shd.Rules({"v": "model"})
    spec = shd.spec_for_axes(("v",), (17,), rules, mesh)  # 17 % 1 == 0 -> ok
    assert spec in (P("model"), P())


def test_divisibility_blocks_sharding():
    mesh = amesh((2, 4), ("data", "model"))
    rules = shd.Rules({"v": "model"})
    assert shd.spec_for_axes(("v",), (10,), rules, mesh) == P()  # 10 % 4 != 0
    assert shd.spec_for_axes(("v",), (12,), rules, mesh) == P("model")


def test_moe_rules_switch_on_expert_count():
    mesh = amesh((2, 4), ("data", "model"))
    few = registry.get_smoke_config("mixtral_8x22b")      # E=4 == |model| -> EP
    many_rules = shd.train_rules(few, mesh)
    assert many_rules.get("experts") == "model"
    import dataclasses
    few2 = dataclasses.replace(few, n_experts=2)          # E=2 < |model| -> TP
    few_rules = shd.train_rules(few2, mesh)
    assert few_rules.get("experts") is None
    assert few_rules.get("expert_mlp") == "model"


def test_param_shardings_cover_tree():
    mesh = amesh((2, 4), ("data", "model"))
    cfg = registry.get_smoke_config("yi_6b")
    pshapes, axes = step_lib.shapes_and_axes(cfg)
    rules = shd.train_rules(cfg, mesh)
    pshard = shd.make_param_shardings(axes, pshapes, rules, mesh)
    n_params = len(jax.tree.leaves(pshapes))
    n_shards = len(jax.tree.leaves(
        pshard, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)))
    assert n_params == n_shards


def test_cache_shardings_paths():
    mesh = amesh((2, 4), ("data", "model"))
    from repro.models import model as M
    import dataclasses
    cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                              n_kv_heads=2, cache_block=8)
    state = jax.eval_shape(lambda: M.init_decode_state(cfg, 8, 256))
    sshard = shd.cache_shardings(state, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sshard)[0]
    by_name = {"/".join(str(getattr(p, "key", "")) for p in path): s
               for path, s in flat}
    for name, s in by_name.items():
        if name.endswith("k_store"):
            # [L, B, Hkv, NB=32, W]: batch -> data, NB -> model
            assert s.spec == P(None, ("data",), None, "model")
        if name.endswith("k_buf"):
            assert s.spec == P(None, ("data",))
        if name.endswith("n_flushed"):
            assert s.spec == P()


def test_batch_sharding_divisibility():
    mesh = amesh((2, 4), ("data", "model"))
    big = jax.ShapeDtypeStruct((8, 16), jnp.int32)
    one = jax.ShapeDtypeStruct((1,), jnp.int32)
    assert shd.batch_sharding(mesh, big).spec == P(("data",), None)
    assert shd.batch_sharding(mesh, one).spec == P()


def test_constrain_noop_under_one_device_mesh():
    # A concrete 1-device mesh (e.g. --mesh 1,1 on a laptop) must leave
    # single-device runs byte-for-byte untouched: constrain returns its
    # argument unchanged — no sharding-constraint ops enter the jaxpr.
    x = jnp.arange(8.0).reshape(2, 4)
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    try:
        shd.set_ambient_mesh(mesh)
        assert shd.constrain(x, "__data__", None) is x
        assert shd.constrain(x, "model", None) is x
        # abstract meshes (trace-time spec construction) are no-ops too
        shd.set_ambient_mesh(amesh((2, 4), ("data", "model")))
        assert shd.constrain(x, "__data__", None) is x
    finally:
        shd.set_ambient_mesh(None)
    assert shd.constrain(x, "__data__", None) is x
