import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop jax's compiled-executable caches after each test module.

    The suite compiles hundreds of distinct executables; on some CPU boxes
    the accumulated jit state eventually segfaults XLA's backend_compile
    partway through the run (the same compilation succeeds in a fresh
    process).  Modules don't share compiled functions — each builds its own
    configs/servers — so clearing between modules costs nothing and keeps
    the per-compilation state bounded to one module's worth.
    """
    yield
    import jax

    jax.clear_caches()
