"""Attention-backend parity suite (DESIGN.md §9).

Every decode-attention backend must agree: the blockwise lazily-dequantized
scan (``xla``), the fused in-situ-decompression kernel (``fused``, pallas and
its vmapped oracle), and the retired materializing oracle, against
``reference_attend`` — across GQA ratios, odd head dims, sliding-window ring
wraparound, and heterogeneous per-row ``nb_valid``/``buf_len`` like the
continuous-batching scheduler produces.  Greedy decode through the full model
must emit bit-identical tokens whichever backend serves it.
"""

import dataclasses
import os
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C
from repro.core import layouts
from repro.kernels import ops

LAYOUTS = ["raw", "packed", "kivi", "huffman"]


def _mk(rng, B, Hkv, G, S, D):
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    return k, v, q


def _all_backends(cache, q):
    """Every decode path's output for one cache, keyed by name."""
    outs = {
        "blockwise": C.attend_blockwise(cache, q),
        "materialized": C.attend_materialized(cache, q),
    }
    if cache.spec.impl.supports_fused:
        outs["fused_pallas"] = ops.cache_decode_attention(cache, q, impl="pallas")
        outs["fused_oracle"] = ops.cache_decode_attention(cache, q, impl="xla")
    return outs


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("G", [1, 4, 8])
def test_backend_parity_gqa(layout, G, rng):
    k, v, q = _mk(rng, 2, 2, G, 72, 16)
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=128,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    cache = C.prefill(spec, k, v)  # 4 blocks + 8 buffered
    outs = _all_backends(cache, q)
    ref = C.reference_attend(k, v, q)
    tol = 0.4 if layout == "kivi" else 0.06
    for name, out in outs.items():
        assert float(jnp.max(jnp.abs(out - ref))) < tol, name
        np.testing.assert_allclose(np.asarray(out), np.asarray(outs["blockwise"]),
                                   atol=5e-3, err_msg=name)


@pytest.mark.parametrize("D", [80, 112, 160])
def test_backend_parity_odd_head_dims(D, rng):
    """Odd head dims from the assigned archs (zamba2 80, chameleon 112, 160)."""
    k, v, q = _mk(rng, 2, 2, 4, 48, D)
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    cache = C.prefill(spec, k, v)
    outs = _all_backends(cache, q)
    for name, out in outs.items():
        np.testing.assert_allclose(np.asarray(out), np.asarray(outs["blockwise"]),
                                   atol=5e-3, err_msg=name)
    # quantization error vs the exact oracle accumulates ~sqrt(D)
    assert float(jnp.max(jnp.abs(outs["blockwise"] - C.reference_attend(k, v, q)))) < 0.2


@pytest.mark.parametrize("layout", ["packed", "raw"])
def test_backend_parity_sliding_window_wraparound(layout, rng):
    """Ring eviction: appends past the window wrap slots; every backend must
    agree with windowed exact attention."""
    k, v, q = _mk(rng, 2, 2, 2, 32, 16)
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=512, window=32,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    cache = C.prefill(spec, k, v)
    app = jax.jit(C.append)
    extra_k = rng.normal(size=(40, 2, 2, 16)).astype(np.float32)
    extra_v = rng.normal(size=(40, 2, 2, 16)).astype(np.float32)
    for t in range(40):
        cache = app(cache, jnp.asarray(extra_k[t]), jnp.asarray(extra_v[t]))
    assert int(cache.n_flushed[0]) > spec.n_blocks  # the ring has wrapped
    k_all = jnp.concatenate([k, jnp.asarray(extra_k).transpose(1, 2, 0, 3)], 2)
    v_all = jnp.concatenate([v, jnp.asarray(extra_v).transpose(1, 2, 0, 3)], 2)
    # Block-aligned eviction retains >= window: the full ring plus whatever
    # sits in the raw buffer (here 2 blocks + 8 buffered = 40 tokens).
    visible = spec.n_blocks * spec.block_size + int(cache.buf_len[0])
    ref = C.reference_attend(k_all, v_all, q, window=visible)
    for name, out in _all_backends(cache, q).items():
        assert float(jnp.max(jnp.abs(out - ref))) < 0.06, name


@pytest.mark.parametrize("layout", ["packed", "raw", "huffman"])
def test_backend_parity_heterogeneous_rows(layout, rng):
    """Rows at different positions (the scheduler's contract): per-row
    nb_valid/buf_len flow into every backend; each row must match its solo
    run bit-for-bit per backend."""
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=256)
    k, v, q = _mk(rng, 2, 2, 2, 96, 16)
    c40 = C.prefill(spec, k[:, :, :40], v[:, :, :40])  # 2 blocks + 8 buffered
    c96 = C.prefill(spec, k, v)                        # 6 blocks + 0 buffered
    mixed = jax.tree.map(lambda a, b: jnp.stack([a[0], b[1]]), c40, c96)
    solo0 = jax.tree.map(lambda x: x[:1], c40)
    solo1 = jax.tree.map(lambda x: x[1:], c96)
    mixed_outs = _all_backends(mixed, q)
    solo0_outs = _all_backends(solo0, q[:1])
    solo1_outs = _all_backends(solo1, q[1:])
    for name in mixed_outs:
        np.testing.assert_array_equal(np.asarray(mixed_outs[name][:1]),
                                      np.asarray(solo0_outs[name]), err_msg=name)
        np.testing.assert_array_equal(np.asarray(mixed_outs[name][1:]),
                                      np.asarray(solo1_outs[name]), err_msg=name)


def test_backend_parity_empty_store_and_empty_buffer(rng):
    """nb_valid == 0 (all in buffer) and buf_len == 0 (all in store)."""
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    k, v, q = _mk(rng, 1, 2, 2, 5, 16)
    cache = C.prefill(spec, k, v)
    assert int(cache.n_flushed[0]) == 0
    ref = C.reference_attend(k, v, q)
    for name, out in _all_backends(cache, q).items():
        assert float(jnp.max(jnp.abs(out - ref))) < 5e-3, name
    k2, v2, q2 = _mk(rng, 1, 2, 2, 32, 16)
    cache2 = C.prefill(spec, k2, v2)
    assert int(cache2.buf_len[0]) == 0
    ref2 = C.reference_attend(k2, v2, q2)
    for name, out in _all_backends(cache2, q2).items():
        assert float(jnp.max(jnp.abs(out - ref2))) < 0.06, name


# ---------------------------------------------------------------------------
# dispatch / registry
# ---------------------------------------------------------------------------


def test_resolve_backend_auto_off_tpu():
    env = {k: v for k, v in os.environ.items() if k != ops.ENV_BACKEND}
    with mock.patch.dict(os.environ, env, clear=True):
        for layout in LAYOUTS:
            assert ops.resolve_backend("auto", layouts.get_layout(layout)) == "xla"


def test_resolve_backend_fused_falls_back_for_ragged_layouts():
    assert ops.resolve_backend("fused", layouts.get_layout("huffman")) == "xla"
    assert ops.resolve_backend("fused", layouts.get_layout("packed")) == "fused"
    assert ops.resolve_backend("fused", layouts.get_layout("raw")) == "fused"


def test_non_fused_layout_has_no_tile_spec_and_kernel_entry_rejects(rng):
    """supports_fused=False is authoritative even when a layout inherits a
    fused-capable base's _tile_decode (huffman subclasses packed): the tile
    spec must be None and the direct kernel entry must raise, not silently
    unpack entropy-coded slots with the packed decoder."""
    spec = C.CacheSpec(layout="huffman", block_size=16, max_seq=64)
    assert spec.impl.tile_decode(spec, 16) is None
    k, v, q = _mk(rng, 1, 2, 2, 32, 16)
    cache = C.prefill(spec, k, v)
    with pytest.raises(ValueError, match="fused-capable layout"):
        ops.cache_decode_attention(cache, q)


def test_resolve_backend_env_override_replaces_auto_only():
    lay = layouts.get_layout("packed")
    with mock.patch.dict(os.environ, {ops.ENV_BACKEND: "fused"}):
        assert ops.resolve_backend("auto", lay) == "fused"
        assert ops.resolve_backend(None, lay) == "fused"
        assert ops.resolve_backend("xla", lay) == "xla"  # explicit wins


def test_resolve_backend_unknown_errors():
    with pytest.raises(ValueError, match="unknown attention backend"):
        ops.resolve_backend("mps", layouts.get_layout("packed"))


def test_register_backend_is_dispatchable(rng):
    calls = []

    @ops.register_backend("_test_probe")
    def _probe(cache, q, scale=None):
        calls.append(cache.spec.layout)
        return C.attend_blockwise(cache, q, scale)

    try:
        spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                           attn_backend="_test_probe")
        k, v, q = _mk(rng, 1, 2, 2, 32, 16)
        cache = C.prefill(spec, k, v)
        out = C.attend(cache, q)
        assert calls == ["packed"]
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(C.attend_blockwise(cache, q)))
    finally:
        ops._BACKENDS.pop("_test_probe", None)


def test_attn_backend_threads_config_to_spec():
    from repro.models.config import ModelConfig
    from repro.core.policy import LayerOverride

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                      vocab_size=64, n_heads=2, n_kv_heads=2,
                      attn_backend="xla",
                      cache_overrides=(LayerOverride(layers=(2,),
                                                     attn_backend="fused"),))
    pol = cfg.compression_policy()
    assert pol.spec_for_layer(0, max_seq=64).attn_backend == "xla"
    assert pol.spec_for_layer(2, max_seq=64).attn_backend == "fused"


# ---------------------------------------------------------------------------
# greedy decode bit-identity across backends (the tentpole contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["packed", "raw"])
def test_greedy_decode_tokens_bit_identical_across_backends(layout, rng):
    import dataclasses as dc

    from repro.models import model as M
    from repro.models.config import ModelConfig

    base = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                       vocab_size=97, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, cache_block=8, cache_layout=layout)
    params, _ = M.init_params(base, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(0, 97, size=(2, 21)).astype(np.int32))

    def run(backend):
        cfg = dc.replace(base, attn_backend=backend)
        logits, state = jax.jit(
            lambda p, t: M.prefill(p, cfg, {"tokens": t}, 64))(params, prompt)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = jnp.full((2,), prompt.shape[1], jnp.int32)
        toks = [tok]
        step = jax.jit(lambda p, t, po, st: M.decode_step(p, cfg, t, po, st))
        for _ in range(12):
            logits, state = step(params, tok, pos, state)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks.append(tok)
            pos = pos + 1
        return np.stack([np.asarray(t) for t in toks])

    t_xla = run("xla")
    t_fused = run("fused")
    np.testing.assert_array_equal(t_xla, t_fused)


def test_spec_backend_dispatch_respected(rng):
    """CacheSpec.attn_backend="fused" routes through the kernel path even on
    CPU (oracle impl), and the result still tracks the blockwise path."""
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                       attn_backend="fused")
    k, v, q = _mk(rng, 1, 2, 2, 40, 16)
    cache = C.prefill(spec, k, v)
    out = C.attend(cache, q)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ops.cache_decode_attention(cache, q)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(C.attend_blockwise(cache, q)),
                               atol=5e-3)
