"""Attention-backend parity suite (DESIGN.md §9).

Every decode-attention backend must agree: the blockwise lazily-dequantized
scan (``xla``), the fused in-situ-decompression kernel (``fused``, pallas and
its vmapped oracle), and the retired materializing oracle, against
``reference_attend`` — across GQA ratios, odd head dims, sliding-window ring
wraparound, and heterogeneous per-row ``nb_valid``/``buf_len`` like the
continuous-batching scheduler produces.  Greedy decode through the full model
must emit bit-identical tokens whichever backend serves it.
"""

import dataclasses
import os
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C
from repro.core import layouts
from repro.kernels import ops

LAYOUTS = ["raw", "packed", "kivi", "huffman"]


def _mk(rng, B, Hkv, G, S, D):
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    return k, v, q


def _all_backends(cache, q):
    """Every decode path's output for one cache, keyed by name."""
    outs = {
        "blockwise": C.attend_blockwise(cache, q),
        "materialized": C.attend_materialized(cache, q),
    }
    if cache.spec.impl.supports_fused:
        outs["fused_pallas"] = ops.cache_decode_attention(cache, q, impl="pallas")
        outs["fused_oracle"] = ops.cache_decode_attention(cache, q, impl="xla")
    return outs


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("G", [1, 4, 8])
def test_backend_parity_gqa(layout, G, rng):
    k, v, q = _mk(rng, 2, 2, G, 72, 16)
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=128,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    cache = C.prefill(spec, k, v)  # 4 blocks + 8 buffered
    outs = _all_backends(cache, q)
    ref = C.reference_attend(k, v, q)
    tol = 0.4 if layout == "kivi" else 0.06
    for name, out in outs.items():
        assert float(jnp.max(jnp.abs(out - ref))) < tol, name
        np.testing.assert_allclose(np.asarray(out), np.asarray(outs["blockwise"]),
                                   atol=5e-3, err_msg=name)


@pytest.mark.parametrize("layout", ["packed", "huffman"])
@pytest.mark.parametrize("D", [80, 112, 160])
def test_backend_parity_odd_head_dims(D, layout, rng):
    """Odd head dims from the assigned archs (zamba2 80, chameleon 112, 160)."""
    k, v, q = _mk(rng, 2, 2, 4, 48, D)
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=64,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    cache = C.prefill(spec, k, v)
    outs = _all_backends(cache, q)
    for name, out in outs.items():
        np.testing.assert_allclose(np.asarray(out), np.asarray(outs["blockwise"]),
                                   atol=5e-3, err_msg=name)
    # quantization error vs the exact oracle accumulates ~sqrt(D)
    assert float(jnp.max(jnp.abs(outs["blockwise"] - C.reference_attend(k, v, q)))) < 0.2


@pytest.mark.parametrize("layout", ["packed", "raw", "huffman"])
def test_backend_parity_sliding_window_wraparound(layout, rng):
    """Ring eviction: appends past the window wrap slots; every backend must
    agree with windowed exact attention."""
    k, v, q = _mk(rng, 2, 2, 2, 32, 16)
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=512, window=32,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    cache = C.prefill(spec, k, v)
    app = jax.jit(C.append)
    extra_k = rng.normal(size=(40, 2, 2, 16)).astype(np.float32)
    extra_v = rng.normal(size=(40, 2, 2, 16)).astype(np.float32)
    for t in range(40):
        cache = app(cache, jnp.asarray(extra_k[t]), jnp.asarray(extra_v[t]))
    assert int(cache.n_flushed[0]) > spec.n_blocks  # the ring has wrapped
    k_all = jnp.concatenate([k, jnp.asarray(extra_k).transpose(1, 2, 0, 3)], 2)
    v_all = jnp.concatenate([v, jnp.asarray(extra_v).transpose(1, 2, 0, 3)], 2)
    # Block-aligned eviction retains >= window: the full ring plus whatever
    # sits in the raw buffer (here 2 blocks + 8 buffered = 40 tokens).
    visible = spec.n_blocks * spec.block_size + int(cache.buf_len[0])
    ref = C.reference_attend(k_all, v_all, q, window=visible)
    for name, out in _all_backends(cache, q).items():
        assert float(jnp.max(jnp.abs(out - ref))) < 0.06, name


@pytest.mark.parametrize("layout", ["packed", "raw", "huffman"])
def test_backend_parity_heterogeneous_rows(layout, rng):
    """Rows at different positions (the scheduler's contract): per-row
    nb_valid/buf_len flow into every backend; each row must match its solo
    run bit-for-bit per backend."""
    spec = C.CacheSpec(layout=layout, block_size=16, max_seq=256)
    k, v, q = _mk(rng, 2, 2, 2, 96, 16)
    c40 = C.prefill(spec, k[:, :, :40], v[:, :, :40])  # 2 blocks + 8 buffered
    c96 = C.prefill(spec, k, v)                        # 6 blocks + 0 buffered
    mixed = jax.tree.map(lambda a, b: jnp.stack([a[0], b[1]]), c40, c96)
    solo0 = jax.tree.map(lambda x: x[:1], c40)
    solo1 = jax.tree.map(lambda x: x[1:], c96)
    mixed_outs = _all_backends(mixed, q)
    solo0_outs = _all_backends(solo0, q[:1])
    solo1_outs = _all_backends(solo1, q[1:])
    for name in mixed_outs:
        np.testing.assert_array_equal(np.asarray(mixed_outs[name][:1]),
                                      np.asarray(solo0_outs[name]), err_msg=name)
        np.testing.assert_array_equal(np.asarray(mixed_outs[name][1:]),
                                      np.asarray(solo1_outs[name]), err_msg=name)


def test_backend_parity_empty_store_and_empty_buffer(rng):
    """nb_valid == 0 (all in buffer) and buf_len == 0 (all in store)."""
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    k, v, q = _mk(rng, 1, 2, 2, 5, 16)
    cache = C.prefill(spec, k, v)
    assert int(cache.n_flushed[0]) == 0
    ref = C.reference_attend(k, v, q)
    for name, out in _all_backends(cache, q).items():
        assert float(jnp.max(jnp.abs(out - ref))) < 5e-3, name
    k2, v2, q2 = _mk(rng, 1, 2, 2, 32, 16)
    cache2 = C.prefill(spec, k2, v2)
    assert int(cache2.buf_len[0]) == 0
    ref2 = C.reference_attend(k2, v2, q2)
    for name, out in _all_backends(cache2, q2).items():
        assert float(jnp.max(jnp.abs(out - ref2))) < 0.06, name


# ---------------------------------------------------------------------------
# dispatch / registry
# ---------------------------------------------------------------------------


def test_resolve_backend_auto_off_tpu():
    env = {k: v for k, v in os.environ.items() if k != ops.ENV_BACKEND}
    with mock.patch.dict(os.environ, env, clear=True):
        for layout in LAYOUTS:
            assert ops.resolve_backend("auto", layouts.get_layout(layout)) == "xla"


def test_resolve_backend_every_builtin_layout_is_fused_capable():
    """Since the huffman in-kernel LUT decode, every built-in layout serves
    through the fused backend when asked."""
    for layout in LAYOUTS:
        assert ops.resolve_backend("fused", layouts.get_layout(layout)) == "fused"


def test_non_fused_layout_has_no_tile_spec_and_kernel_entry_rejects(rng):
    """supports_fused=False is authoritative even when a layout inherits a
    fused-capable base's _tile_decode (a custom layout subclassing packed
    with a different slot encoding): the tile spec must be None, a fused
    request must fall back to the blockwise floor, and the direct kernel
    entry must raise — not silently unpack the slots with the packed
    decoder."""

    class _Ragged(layouts.PackedLayout):
        supports_fused = False

    layouts.register_layout("_test_ragged")(_Ragged)
    try:
        lay = layouts.get_layout("_test_ragged")
        assert ops.resolve_backend("fused", lay) == "xla"
        spec = C.CacheSpec(layout="_test_ragged", block_size=16, max_seq=64)
        assert spec.impl.tile_decode(spec, 16) is None
        k, v, q = _mk(rng, 1, 2, 2, 32, 16)
        cache = C.prefill(spec, k, v)
        with pytest.raises(ValueError, match="fused-capable layout"):
            ops.cache_decode_attention(cache, q)
    finally:
        layouts._REGISTRY.pop("_test_ragged", None)


def test_resolve_backend_env_override_replaces_auto_only():
    lay = layouts.get_layout("packed")
    with mock.patch.dict(os.environ, {ops.ENV_BACKEND: "fused"}):
        assert ops.resolve_backend("auto", lay) == "fused"
        assert ops.resolve_backend(None, lay) == "fused"
        assert ops.resolve_backend("xla", lay) == "xla"  # explicit wins


def test_resolve_backend_unknown_errors():
    with pytest.raises(ValueError, match="unknown attention backend"):
        ops.resolve_backend("mps", layouts.get_layout("packed"))


def test_register_backend_is_dispatchable(rng):
    calls = []

    @ops.register_backend("_test_probe")
    def _probe(cache, q, scale=None):
        calls.append(cache.spec.layout)
        return C.attend_blockwise(cache, q, scale)

    try:
        spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                           attn_backend="_test_probe")
        k, v, q = _mk(rng, 1, 2, 2, 32, 16)
        cache = C.prefill(spec, k, v)
        out = C.attend(cache, q)
        assert calls == ["packed"]
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(C.attend_blockwise(cache, q)))
    finally:
        ops._BACKENDS.pop("_test_probe", None)


def test_attn_backend_threads_config_to_spec():
    from repro.models.config import ModelConfig
    from repro.core.policy import LayerOverride

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                      vocab_size=64, n_heads=2, n_kv_heads=2,
                      attn_backend="xla",
                      cache_overrides=(LayerOverride(layers=(2,),
                                                     attn_backend="fused"),))
    pol = cfg.compression_policy()
    assert pol.spec_for_layer(0, max_seq=64).attn_backend == "xla"
    assert pol.spec_for_layer(2, max_seq=64).attn_backend == "fused"


# ---------------------------------------------------------------------------
# blockwise span/unroll knobs
# ---------------------------------------------------------------------------


def test_blockwise_knobs_precedence(monkeypatch):
    """Spec field > REPRO_BLOCKWISE_* env var > module default."""
    monkeypatch.delenv(C.ENV_SPAN_TOKENS, raising=False)
    monkeypatch.delenv(C.ENV_UNROLL_MAX, raising=False)
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64)
    assert C.blockwise_knobs(spec) == (C.BLOCKWISE_SPAN_TOKENS,
                                       C.BLOCKWISE_UNROLL_MAX)
    monkeypatch.setenv(C.ENV_SPAN_TOKENS, "128")
    monkeypatch.setenv(C.ENV_UNROLL_MAX, "3")
    assert C.blockwise_knobs(spec) == (128, 3)
    pinned = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                         span_tokens=32, unroll_max=7)
    assert C.blockwise_knobs(pinned) == (32, 7)  # explicit spec wins
    with pytest.raises(ValueError, match="span_tokens"):
        C.CacheSpec(layout="packed", span_tokens=0)
    # env values get the same validation as spec fields, with a clear error
    monkeypatch.setenv(C.ENV_UNROLL_MAX, "0")
    with pytest.raises(ValueError, match=C.ENV_UNROLL_MAX):
        C.blockwise_knobs(spec)
    monkeypatch.setenv(C.ENV_UNROLL_MAX, "3")
    monkeypatch.setenv(C.ENV_SPAN_TOKENS, "1k")
    with pytest.raises(ValueError, match="not an integer"):
        C.blockwise_knobs(spec)


def test_blockwise_output_invariant_to_span_and_unroll(rng):
    """Any span size / unroll-vs-scan choice computes the same attention
    (the knob only trades peak temps for per-step overhead)."""
    k, v, q = _mk(rng, 2, 2, 2, 96, 16)
    base = C.attend_blockwise(
        C.prefill(C.CacheSpec(layout="packed", block_size=16, max_seq=128),
                  k, v), q)
    for span_tokens, unroll_max in [(16, 64), (48, 64), (16, 1)]:
        spec = C.CacheSpec(layout="packed", block_size=16, max_seq=128,
                           span_tokens=span_tokens, unroll_max=unroll_max)
        out = C.attend_blockwise(C.prefill(spec, k, v), q)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=1e-5, err_msg=f"{span_tokens}/{unroll_max}")


def test_span_knobs_thread_config_to_spec():
    from repro.models.config import ModelConfig
    from repro.core.policy import LayerOverride

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                      vocab_size=64, n_heads=2, n_kv_heads=2,
                      cache_span_tokens=256, cache_unroll_max=4,
                      cache_overrides=(LayerOverride(layers=(2,),
                                                     span_tokens=64,
                                                     unroll_max=1),))
    pol = cfg.compression_policy()
    s0 = pol.spec_for_layer(0, max_seq=64)
    assert (s0.span_tokens, s0.unroll_max) == (256, 4)
    s2 = pol.spec_for_layer(2, max_seq=64)
    assert (s2.span_tokens, s2.unroll_max) == (64, 1)


# ---------------------------------------------------------------------------
# greedy decode bit-identity across backends (the tentpole contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["packed", "raw", "huffman"])
def test_greedy_decode_tokens_bit_identical_across_backends(layout, rng):
    import dataclasses as dc

    from repro.models import model as M
    from repro.models.config import ModelConfig

    base = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                       vocab_size=97, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, cache_block=8, cache_layout=layout)
    params, _ = M.init_params(base, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(0, 97, size=(2, 21)).astype(np.int32))

    def run(backend):
        cfg = dc.replace(base, attn_backend=backend)
        logits, state = jax.jit(
            lambda p, t: M.prefill(p, cfg, {"tokens": t}, 64))(params, prompt)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = jnp.full((2,), prompt.shape[1], jnp.int32)
        toks = [tok]
        step = jax.jit(lambda p, t, po, st: M.decode_step(p, cfg, t, po, st))
        for _ in range(12):
            logits, state = step(params, tok, pos, state)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks.append(tok)
            pos = pos + 1
        return np.stack([np.asarray(t) for t in toks])

    t_xla = run("xla")
    t_fused = run("fused")
    np.testing.assert_array_equal(t_xla, t_fused)


def test_huffman_tile_decode_bit_exact_vs_blockwise_decode(rng):
    """Losslessness of the fused path: the in-kernel chunked-LUT tile decode
    must reproduce the layout's blockwise entropy decode bit-for-bit (same
    codes, same dequant ops) for every slot — the kernel/oracle/blockwise
    paths then differ only in softmax accumulation order."""
    from repro.kernels import ref

    spec = C.CacheSpec(layout="huffman", block_size=16, max_seq=128,
                       rel_scale_k=0.02, rel_scale_v=0.05)
    k, v, q = _mk(rng, 2, 2, 2, 64, 24)
    cache = C.prefill(spec, k, v)
    lay, D = spec.impl, cache.head_dim
    tile = lay.tile_decode(spec, D)
    assert tile is not None and len(tile.aux) == 2
    aux = tuple(jnp.asarray(a) for a in tile.aux)
    k_codes = lay._decode(spec, cache.k_store, D, lay.book_k(spec))
    v_codes = lay._decode(spec, cache.v_store, D, lay.book_v(spec))
    for b in range(2):
        for h in range(2):
            for n in range(4):
                kd = tile.decode_k(cache.k_store[b, h, n], cache.k_min[b, h, n],
                                   cache.k_step[b, h, n], *aux)
                vd = tile.decode_v(cache.v_store[b, h, n], cache.v_min[b, h, n],
                                   cache.v_step[b, h, n], *aux)
                np.testing.assert_array_equal(
                    np.asarray(kd),
                    np.asarray(ref.dequant_k(k_codes[b, h, n],
                                             cache.k_min[b, h, n],
                                             cache.k_step[b, h, n])))
                np.testing.assert_array_equal(
                    np.asarray(vd),
                    np.asarray(ref.dequant_v(v_codes[b, h, n],
                                             cache.v_min[b, h, n],
                                             cache.v_step[b, h, n])))


def test_huffman_fused_pallas_matches_oracle_bit_level(rng):
    """Kernel vs vmapped-oracle parity for the huffman ragged-payload tile
    decode, through the public jit'd entry (both impls share the same
    FusedTileSpec closures, so any drift is accumulation order only)."""
    spec = C.CacheSpec(layout="huffman", block_size=16, max_seq=128)
    k, v, q = _mk(rng, 2, 2, 4, 72, 16)
    cache = C.prefill(spec, k, v)
    o_pallas = ops.cache_decode_attention(cache, q, impl="pallas")
    o_oracle = ops.cache_decode_attention(cache, q, impl="xla")
    np.testing.assert_allclose(np.asarray(o_pallas), np.asarray(o_oracle),
                               atol=1e-5, rtol=1e-5)


def test_spec_backend_dispatch_respected(rng):
    """CacheSpec.attn_backend="fused" routes through the kernel path even on
    CPU (oracle impl), and the result still tracks the blockwise path."""
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64,
                       attn_backend="fused")
    k, v, q = _mk(rng, 1, 2, 2, 40, 16)
    cache = C.prefill(spec, k, v)
    out = C.attend(cache, q)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ops.cache_decode_attention(cache, q)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(C.attend_blockwise(cache, q)),
                               atol=5e-3)
