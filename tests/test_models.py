"""Per-architecture smoke tests: one reduced-config forward/train step per
assigned arch (shapes + finiteness), plus prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models import registry


def _batch(cfg, rng, B=2, S=16):
    if cfg.input_mode == "tokens":
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    return {"embeddings": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}


@pytest.mark.parametrize("arch", registry.ASSIGNED)
def test_arch_smoke_forward_and_grad(arch, rng):
    cfg = registry.get_smoke_config(arch)
    params, axes = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux = M.forward(params, cfg, batch, q_chunk=8, kv_chunk=8)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    def loss_fn(p):
        loss, _ = M.lm_loss(p, cfg, batch, q_chunk=8, kv_chunk=8)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["yi_6b", "qwen3_1_7b", "mamba2_1_3b",
                                  "zamba2_7b", "mixtral_8x22b"])
def test_prefill_matches_forward(arch, rng):
    cfg = registry.get_smoke_config(arch)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    logits, _ = M.forward(params, cfg, batch, q_chunk=8, kv_chunk=8)
    lp, state = M.prefill(params, cfg, batch, max_seq=64, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_1_3b", "zamba2_7b"])
def test_decode_consistency_raw_cache(arch, rng):
    """Step-by-step decode == full forward when the cache is exact (raw
    layout, no MoE capacity effects)."""
    cfg = registry.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    _, state = M.prefill(params, cfg, batch, max_seq=64, q_chunk=8, kv_chunk=8)
    toks = batch["tokens"]
    pos = S
    for t in range(3):
        nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)))
        lg, state = M.decode_step(params, cfg, nxt, jnp.asarray(pos, jnp.int32), state)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
        full, _ = M.forward(params, cfg, {"tokens": toks}, q_chunk=8, kv_chunk=8)
        err = float(jnp.max(jnp.abs(lg - full[:, -1])))
        assert err < 0.05, (arch, t, err)
        pos += 1


def test_compressed_cache_decode_tracks_raw(rng):
    """packed-layout decode logits stay close to raw-layout logits.

    Root cause of the historical flake: with random (untrained) weights the
    logit distribution is nearly flat, so a row whose top-1 margin is below
    the quantization noise floor can legitimately flip its argmax across
    environments (XLA version / platform numerics).  The stable contract is
    noise-bounded: logits stay highly correlated, and the compressed argmax
    is always within the raw noise band of the raw maximum — which implies
    exact argmax agreement whenever the decision margin exceeds the noise
    (the trained-model regime; see test_system's serving-agreement test).
    """
    base = registry.get_smoke_config("yi_6b")
    batch = _batch(base, rng, 2, 24)  # ONE batch shared across layouts
    outs = {}
    for layout in ("raw", "packed"):
        cfg = dataclasses.replace(base, cache_layout=layout,
                                  rel_scale_k=0.02, rel_scale_v=0.05)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(3))
        _, state = M.prefill(params, cfg, batch, max_seq=64, q_chunk=8, kv_chunk=8)
        nxt = jnp.asarray([5, 7])
        lg, _ = M.decode_step(params, cfg, nxt, jnp.asarray(24, jnp.int32), state)
        outs[layout] = np.asarray(lg)
    corr = np.corrcoef(outs["raw"].ravel(), outs["packed"].ravel())[0, 1]
    assert corr > 0.99, corr
    noise = np.abs(outs["raw"] - outs["packed"]).max()
    assert noise < 0.5, noise  # rel_scale 0.02/0.05 keeps logit noise small
    # the compressed winner's raw logit is within the noise band of the top
    raw_at_packed_argmax = np.take_along_axis(
        outs["raw"], outs["packed"].argmax(-1)[:, None], axis=-1)[:, 0]
    gap = outs["raw"].max(-1) - raw_at_packed_argmax
    assert (gap <= 2 * noise + 1e-6).all(), (gap, noise)
    # rows whose decision margin clears the noise must agree exactly
    top2 = np.partition(outs["raw"], -2, axis=-1)[:, -2:]
    margin = top2[:, 1] - top2[:, 0]
    decided = margin > 2 * noise
    agree = outs["raw"].argmax(-1) == outs["packed"].argmax(-1)
    assert agree[decided].all(), (margin, noise, agree)


def test_param_count_analytic_matches_actual():
    for arch in ["yi_6b", "mamba2_1_3b", "zamba2_7b", "qwen3_moe_30b_a3b"]:
        cfg = registry.get_smoke_config(arch)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # analytic formula ignores a few tiny vectors; agree within 2%
        assert abs(actual - analytic) / actual < 0.02, (arch, actual, analytic)


def test_full_configs_match_spec():
    """The full (assigned) configs encode the published hyperparameters."""
    c = registry.get_config("mixtral_8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (56, 6144, 48, 8)
    assert (c.n_experts, c.top_k, c.d_ff_expert, c.vocab_size) == (8, 2, 16384, 32768)
    c = registry.get_config("qwen3_moe_30b_a3b")
    assert (c.n_experts, c.top_k, c.d_ff_expert) == (128, 8, 768)
    c = registry.get_config("zamba2_7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.hybrid_period) == (81, 3584, 64, 6)
    c = registry.get_config("mamba2_1_3b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == (48, 2048, 128, 50280)
    c = registry.get_config("hubert_xlarge")
    assert c.encoder_only and c.input_mode == "embeddings"
    assert (c.n_layers, c.d_model, c.vocab_size) == (48, 1280, 504)


def test_encoder_is_bidirectional(rng):
    """Perturbing a late token changes an early token's logits (no mask)."""
    cfg = registry.get_smoke_config("hubert_xlarge")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(4))
    emb = rng.normal(size=(1, 16, cfg.d_model)).astype(np.float32)
    l1, _ = M.forward(params, cfg, {"embeddings": jnp.asarray(emb)}, q_chunk=8, kv_chunk=8)
    emb2 = emb.copy()
    emb2[0, -1] += 10.0
    l2, _ = M.forward(params, cfg, {"embeddings": jnp.asarray(emb2)}, q_chunk=8, kv_chunk=8)
    assert float(jnp.max(jnp.abs(l1[0, 0] - l2[0, 0]))) > 1e-4


def test_causal_lm_is_causal(rng):
    cfg = registry.get_smoke_config("yi_6b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(5))
    t1 = rng.integers(0, cfg.vocab_size, (1, 16))
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % cfg.vocab_size
    l1, _ = M.forward(params, cfg, {"tokens": jnp.asarray(t1)}, q_chunk=8, kv_chunk=8)
    l2, _ = M.forward(params, cfg, {"tokens": jnp.asarray(t2)}, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               atol=1e-5)
