"""Distributed correctness on 8 simulated devices (subprocess — the fake
device count must not leak into other tests' jax runtime)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str) -> dict:
    prog = textwrap.dedent(code)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = run_sub("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import registry, model as M
        from repro.train import step as step_lib
        from repro.optim import adamw
        from repro.data.pipeline import SyntheticCorpus

        cfg = registry.get_smoke_config("yi_6b")
        data = SyntheticCorpus(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
        batch_np = data.batch_at(0)
        bspecs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch_np.items()}
        scfg = step_lib.TrainStepConfig(remat=False, q_chunk=32, kv_chunk=32,
                                        opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                                              total_steps=10))
        losses = {}
        for shape, axes in [((4, 2), ("data", "model")), ((1, 1), ("data", "model"))]:
            n = shape[0] * shape[1]
            from repro.distributed.sharding import make_mesh
            mesh = make_mesh(shape, axes, devices=jax.devices()[:n])
            step, shapes, in_sh, out_sh = step_lib.build_train_artifacts(
                cfg, mesh, scfg, bspecs)
            with mesh:
                params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
                params = jax.device_put(params, in_sh[0][0])
                opt = jax.jit(adamw.init, out_shardings=in_sh[0][1])(params)
                batch = {k: jax.device_put(v, in_sh[1][k]) for k, v in batch_np.items()}
                state = (params, opt, None)
                for _ in range(3):
                    state, metrics = jax.jit(step, in_shardings=in_sh,
                                             out_shardings=out_sh)(state, batch)
            losses[str(shape)] = float(metrics["loss"])
        print(json.dumps(losses))
    """)
    vals = list(res.values())
    assert abs(vals[0] - vals[1]) < 1e-3, res


def test_cross_pod_grad_compress_runs():
    res = run_sub("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import registry, model as M
        from repro.train import step as step_lib
        from repro.optim import adamw
        from repro.data.pipeline import SyntheticCorpus

        cfg = registry.get_smoke_config("qwen3_1_7b")
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        data = SyntheticCorpus(seq_len=16, global_batch=8, vocab_size=cfg.vocab_size)
        batch_np = data.batch_at(0)
        bspecs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch_np.items()}
        scfg = step_lib.TrainStepConfig(remat=False, q_chunk=16, kv_chunk=16,
                                        cross_pod_grad_compress=True)
        step, shapes, in_sh, out_sh = step_lib.build_train_artifacts(
            cfg, mesh, scfg, bspecs)
        from repro.optim import grad_compress
        with mesh:
            params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
            params = jax.device_put(params, in_sh[0][0])
            opt = jax.jit(adamw.init, out_shardings=in_sh[0][1])(params)
            err = jax.jit(grad_compress.init_error_state,
                          out_shardings=in_sh[0][2])(params)
            batch = {k: jax.device_put(v, in_sh[1][k]) for k, v in batch_np.items()}
            state = (params, opt, err)
            for _ in range(2):
                state, metrics = jax.jit(step, in_shardings=in_sh,
                                         out_shardings=out_sh)(state, batch)
        ok = bool(np.isfinite(float(metrics["loss"])))
        print(json.dumps({"ok": ok, "loss": float(metrics["loss"])}))
    """)
    assert res["ok"], res


def test_serve_decode_sharded_matches_unsharded():
    res = run_sub("""
        import json, dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import registry, model as M
        from repro.distributed import sharding as shd
        from repro.train import step as step_lib

        cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                                  n_kv_heads=2, cache_block=8)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        B, S = 4, 64
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
        _, state = M.prefill(params, cfg, batch, max_seq=128, q_chunk=16, kv_chunk=16)
        nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)))
        ref, _ = M.decode_step(params, cfg, nxt, jnp.asarray(S, jnp.int32), state)

        mesh = shd.make_mesh((2, 4), ("data", "model"))
        pshapes, axes = step_lib.shapes_and_axes(cfg)
        rules = shd.serve_rules(cfg, mesh)
        pshard = shd.make_param_shardings(axes, pshapes, rules, mesh)
        # cast params to cfg dtype tree of pshapes? params are f32; reuse spec tree
        pshard = jax.tree.map(lambda s: s, pshard)
        sstate_shapes = jax.eval_shape(lambda: state)
        sshard = shd.cache_shardings(sstate_shapes, mesh)
        with mesh:
            params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, pshard)
            state_s = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sshard)
            out, _ = jax.jit(lambda p, t, pos, st: M.decode_step(p, cfg, t, pos, st),
                             in_shardings=(pshard, None, None, sshard))(
                params_s, nxt, jnp.asarray(S, jnp.int32), state_s)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-2, res
