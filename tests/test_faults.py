"""Request-lifecycle hardening (DESIGN.md §15): failure isolation,
cancellation & deadlines, admission backpressure, and the deterministic
fault-injection + invariant-audit harness.

The load-bearing contracts:

* A pool-exhaustion event (injected or real) fails or requeues ONLY the
  affected request — every surviving stream's greedy tokens are
  bit-identical to a fault-free run, and the invariant auditor stays clean
  (refcounts balanced, pages released, host == device page tables).
* ``FaultPlan`` is deterministic: the same ``(seed, rates, at)`` produce
  the same firing schedule in any process, so the chaos soak replays
  exactly from its printed seed (``REPRO_CHAOS_SEED``).
* Cancel/deadline retire a request from ANY state (queued, PREFILLING,
  decoding) through the same cleanup path failures use.
* A provably stuck server raises a descriptive ``ServeError`` instead of
  letting ``Handle.result()`` spin forever.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import zlib
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import pool as blockpool
from repro.models import model as M
from repro.models import registry
from repro.serve.faults import (FAULT_SITES, FaultPlan, InvariantViolation,
                                QueueFull, ServeError)
from repro.serve.scheduler import Request, Server, ServerConfig

SRC = str(Path(__file__).resolve().parents[1] / "src")

# The chaos soak's replay knob: a failure prints this seed, and exporting
# it reruns the identical fault schedule.
CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "20260808"))

LENS = (7, 13, 19, 26)
NEWS = (3, 6, 4, 5)


# ---------------------------------------------------------------------------
# FaultPlan: pure-host determinism contracts
# ---------------------------------------------------------------------------


def test_fault_plan_rates_deterministic_across_instances():
    mk = lambda: FaultPlan(seed=7, rates={"reclaim_sweep": 0.3,
                                          "pool_alloc": 0.1})
    a, b = mk(), mk()
    seq_a = [a.fire(s) for _ in range(200) for s in ("reclaim_sweep",
                                                     "pool_alloc")]
    seq_b = [b.fire(s) for _ in range(200) for s in ("reclaim_sweep",
                                                     "pool_alloc")]
    assert seq_a == seq_b
    assert a.fired == b.fired
    assert any(seq_a) and not all(seq_a)
    # per-site independence: interleaving order does not perturb a site's
    # own schedule (each site draws from its own generator)
    c = FaultPlan(seed=7, rates={"reclaim_sweep": 0.3, "pool_alloc": 0.1})
    only = [c.fire("reclaim_sweep") for _ in range(200)]
    assert only == [f for f, s in zip(seq_a, ["reclaim_sweep",
                                              "pool_alloc"] * 200)
                    if s == "reclaim_sweep"]
    # a different seed yields a different schedule
    d = FaultPlan(seed=8, rates={"reclaim_sweep": 0.3, "pool_alloc": 0.1})
    assert [d.fire("reclaim_sweep") for _ in range(200)] != only


def test_fault_plan_at_exact_visits_and_stats():
    p = FaultPlan(at={"chunk_prefill": (1, 3)})
    fires = [p.fire("chunk_prefill") for _ in range(5)]
    assert fires == [True, False, True, False, False]
    assert p.fired == [("chunk_prefill", 1), ("chunk_prefill", 3)]
    st = p.stats()
    assert st["visits"]["chunk_prefill"] == 5
    assert st["fired"] == [["chunk_prefill", 1], ["chunk_prefill", 3]]
    # unconfigured sites never fire but are still counted
    assert not p.fire("pool_alloc")
    assert p.stats()["visits"]["pool_alloc"] == 1


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(rates={"gpu_on_fire": 1.0})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(at={"nope": (1,)})
    with pytest.raises(ValueError, match=r"in \[0, 1\]"):
        FaultPlan(rates={"pool_alloc": 1.5})


def test_server_config_lifecycle_validation():
    ok = dict(max_slots=2, max_seq=64)
    with pytest.raises(ValueError, match="max_requeues"):
        ServerConfig(**ok, max_requeues=-1)
    with pytest.raises(ValueError, match="max_pending"):
        ServerConfig(**ok, max_pending=0)
    with pytest.raises(ValueError, match="backpressure"):
        ServerConfig(**ok, backpressure="drop")
    with pytest.raises(ValueError, match="default_deadline_s"):
        ServerConfig(**ok, default_deadline_s=0.0)
    with pytest.raises(ValueError, match="audit_every"):
        ServerConfig(**ok, audit_every=-1)
    with pytest.raises(ValueError, match="stall_steps"):
        ServerConfig(**ok, stall_steps=0)


# ---------------------------------------------------------------------------
# Server integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("yi_6b")
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate([
        shared, rng.integers(0, cfg.vocab_size, L).astype(np.int32)])
        for L in LENS]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def pressure(setup):
    """A workload whose PRESSURE comes from decode growth, not prompt
    size: short prompts all admit easily onto the 6-page pool, then each
    row's ring grows toward ~5 pages — two live rows overcommit the arena
    and the reclaim ladder genuinely runs mid-decode."""
    cfg, _, _ = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32)
               for L in (8, 9, 10, 11)]
    return prompts, (40, 38, 36, 34)


def page_bytes(cfg) -> int:
    """One arena page's byte cost summed over layers — the unit
    ServerConfig.pool_hbm_bytes is divided by."""
    return sum(blockpool.page_nbytes(s, cfg.n_kv_heads, cfg.resolved_head_dim)
               for s in M.cache_specs(cfg, 128))


def make_server(cfg, params, **kw):
    return Server(cfg, params, ServerConfig(max_slots=2, max_seq=128, **kw),
                  q_chunk=32, kv_chunk=32)


def run_all(server, prompts, news=NEWS):
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, news)]
    server.run()
    return handles, [h.result() for h in handles]


def lifecycle(server) -> dict:
    return server.stats()["lifecycle"]


@pytest.mark.parametrize("pfx", ["off", "on"])
def test_pool_exhaustion_fails_only_the_victim(setup, pressure, pfx):
    """The tentpole regression: with the reclaim ladder's victim sweep
    forced to come up empty (the old hard-RuntimeError path) and zero
    requeue budget, only the requesting stream fails — survivors are
    bit-identical to the fault-free run under the same pool pressure, and
    the auditor finds refcounts balanced and pages released."""
    cfg, params, _ = setup
    prompts, news = pressure
    pool = dict(cache_mode="paged", prefix_cache=pfx,
                pool_hbm_bytes=6 * page_bytes(cfg))
    clean = make_server(cfg, params, **pool, audit_every=1)
    _, base = run_all(clean, prompts, news)
    assert all(r.finish_reason in ("eos", "length") for r in base)
    assert clean.preemptions > 0  # the 6-page pool creates real pressure
    assert clean.auditor.report()["clean"]

    # Only the victim sweep is faulted: prefix-index eviction stays real
    # (faulting it too would legitimately deadlock admission behind
    # index-parked pages — the stall detector's job, tested separately).
    plan = FaultPlan(rates={"reclaim_sweep": 1.0})
    srv = make_server(cfg, params, **pool, faults=plan, max_requeues=0,
                      audit_every=1)
    _, res = run_all(srv, prompts, news)
    failed = [i for i, r in enumerate(res) if r.finish_reason == "error"]
    assert failed, "forced victimless reclaim never failed a request"
    assert len(failed) < len(res), "failure was not isolated"
    for i in failed:
        assert "pool exhausted with no reclaimable pages" in res[i].error
        assert f"request {i}" in res[i].error
    for i, r in enumerate(res):
        if i not in failed:  # survivors: bit-identical greedy streams
            assert r.finish_reason == base[i].finish_reason
            assert r.tokens.tolist() == base[i].tokens.tolist(), i
            assert r.error is None
    assert srv.auditor.report()["clean"], srv.auditor.report()
    lc = lifecycle(srv)
    assert lc["failures"] == len(failed)
    assert plan.fired  # the schedule actually fired
    # shutdown snapshot carries the audit + fault evidence (the CI artifact)
    snap = srv.shutdown()
    assert snap["audit"]["clean"] and snap["faults"]["fired"]


def test_requeue_backoff_within_budget_is_invisible(setup, pressure):
    """Under the same forced victimless sweeps, a nonzero requeue budget
    absorbs every event: all four requests finish with bit-identical
    tokens, no failures, and the requeue counter shows the absorbed
    faults."""
    cfg, params, _ = setup
    prompts, news = pressure
    pool = dict(cache_mode="paged", pool_hbm_bytes=6 * page_bytes(cfg))
    clean = make_server(cfg, params, **pool)
    _, base = run_all(clean, prompts, news)
    plan = FaultPlan(rates={"reclaim_sweep": 1.0})
    srv = make_server(cfg, params, **pool, faults=plan, max_requeues=8,
                      audit_every=1)
    _, res = run_all(srv, prompts, news)
    assert [r.tokens.tolist() for r in res] == \
        [r.tokens.tolist() for r in base]
    assert all(r.finish_reason in ("eos", "length") for r in res)
    lc = lifecycle(srv)
    assert lc["failures"] == 0 and lc["requeues"] > 0
    assert srv.auditor.report()["clean"]


def test_chunk_prefill_fault_requeues_one_task_bit_identically(setup):
    """An injected chunk-dispatch failure (dense mode: no pool in play)
    requeues exactly the struck task; the replayed prefill reproduces the
    identical stream."""
    cfg, params, prompts = setup
    clean = make_server(cfg, params)
    _, base = run_all(clean, prompts)
    plan = FaultPlan(at={"chunk_prefill": (1,)})
    srv = make_server(cfg, params, faults=plan, audit_every=1)
    _, res = run_all(srv, prompts)
    assert [r.tokens.tolist() for r in res] == \
        [r.tokens.tolist() for r in base]
    lc = lifecycle(srv)
    assert lc["requeues"] == 1 and lc["failures"] == 0
    assert plan.fired == [("chunk_prefill", 1)]
    assert srv.auditor.report()["clean"]


def test_decode_dispatch_fault_only_delays(setup):
    """Transient decode-dispatch failures skip the step and retry: tokens
    are delayed, never changed or dropped."""
    cfg, params, prompts = setup
    clean = make_server(cfg, params)
    _, base = run_all(clean, prompts)
    srv = make_server(cfg, params, audit_every=1,
                      faults=FaultPlan(seed=1,
                                       rates={"decode_dispatch": 0.5}))
    _, res = run_all(srv, prompts)
    assert [r.tokens.tolist() for r in res] == \
        [r.tokens.tolist() for r in base]
    assert lifecycle(srv)["failures"] == 0
    assert srv.auditor.report()["clean"]


def test_cancel_queued_and_live(setup):
    """Handle.cancel() retires a request from the queue (no tokens, no
    slot) and mid-decode (partial tokens kept), through the same cleanup
    path failures use — pages released, survivors unaffected."""
    cfg, params, prompts = setup
    clean = make_server(cfg, params, cache_mode="paged", prefix_cache="on")
    _, base = run_all(clean, prompts)

    srv = make_server(cfg, params, cache_mode="paged", prefix_cache="on",
                      audit_every=1)
    handles = [srv.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    # 2 slots: requests 2 and 3 are still queued right after submit
    assert handles[3].cancel()
    assert not handles[3].cancel()  # second cancel: already finished
    srv.step()  # admits + decodes a step; request 0 is live now
    assert handles[0].cancel()
    srv.run()
    res = [h.result() for h in handles]
    assert res[3].finish_reason == "cancelled"
    assert len(res[3].tokens) == 0 and res[3].ttft_s is None
    assert res[3].gen_s == 0.0 and res[3].error is None
    assert res[0].finish_reason == "cancelled"
    # the untouched streams match the fault-free run bit for bit
    for i in (1, 2):
        assert res[i].finish_reason == base[i].finish_reason
        assert res[i].tokens.tolist() == base[i].tokens.tolist()
    lc = lifecycle(srv)
    assert lc["cancelled"] == 2 and lc["failures"] == 0
    # token-less results never pollute the TTFT histogram
    n_with_tokens = sum(1 for r in res if len(r.tokens))
    assert srv.stats()["latency"]["ttft_s"]["count"] == n_with_tokens
    assert srv.auditor.report()["clean"]


def test_deadlines_default_and_per_request(setup):
    cfg, params, prompts = setup
    # A microscopic default deadline expires everything before any token.
    srv = make_server(cfg, params, cache_mode="paged",
                      default_deadline_s=1e-6, audit_every=1)
    _, res = run_all(srv, prompts)
    assert all(r.finish_reason == "deadline" for r in res)
    assert all(len(r.tokens) == 0 and r.ttft_s is None and r.gen_s == 0.0
               for r in res)
    assert lifecycle(srv)["deadline_exceeded"] == len(res)
    assert srv.stats()["latency"]["ttft_s"]["count"] == 0
    assert srv.auditor.report()["clean"]

    # Request.deadline_s overrides per request: only the marked one dies.
    srv2 = make_server(cfg, params, cache_mode="paged", audit_every=1)
    hs = [srv2.submit(Request(prompt=p, max_new_tokens=n,
                              deadline_s=1e-6 if i == 3 else None))
          for i, (p, n) in enumerate(zip(prompts, NEWS))]
    srv2.run()
    res2 = [h.result() for h in hs]
    assert res2[3].finish_reason == "deadline"
    assert all(r.finish_reason in ("eos", "length") for r in res2[:3])
    assert lifecycle(srv2)["deadline_exceeded"] == 1
    with pytest.raises(ValueError, match="deadline_s"):
        srv2.submit(Request(prompt=prompts[0], max_new_tokens=2,
                            deadline_s=0.0))


def test_backpressure_reject_and_block(setup):
    cfg, params, prompts = setup
    srv = make_server(cfg, params, max_pending=2)
    hs = [srv.submit(Request(prompt=prompts[i], max_new_tokens=NEWS[i]))
          for i in range(2)]
    with pytest.raises(QueueFull, match="max_pending=2"):
        srv.submit(Request(prompt=prompts[2], max_new_tokens=3))
    assert lifecycle(srv)["rejected"] == 1
    srv.run()
    assert all(h.result().finish_reason in ("eos", "length") for h in hs)

    # "block" drives the server inside submit until the queue drains —
    # every request is accepted and completes.
    srv2 = make_server(cfg, params, max_pending=1, backpressure="block")
    hs2 = [srv2.submit(Request(prompt=p, max_new_tokens=n))
           for p, n in zip(prompts, NEWS)]
    srv2.run()
    assert all(h.result().finish_reason in ("eos", "length") for h in hs2)
    assert lifecycle(srv2)["rejected"] == 0


def test_no_progress_raises_descriptive_serve_error(setup):
    """A server that can never admit (persistent injected exhaustion at
    the admission check) must raise a ServeError naming the stuck request
    instead of letting Handle.result() spin forever."""
    cfg, params, prompts = setup
    srv = make_server(cfg, params, cache_mode="paged", stall_steps=16,
                      faults=FaultPlan(rates={"pool_alloc": 1.0}))
    h = srv.submit(Request(prompt=prompts[0], max_new_tokens=2))
    with pytest.raises(ServeError, match=r"no progress for 16 .*request 0"):
        h.result()
    assert not h.done  # the request is stuck, not silently failed


def test_deadline_exempts_stall_detection(setup):
    """While an unexpired deadline pends, zero-progress steps are not a
    stall — wall-clock time retires the request, and the server drains
    instead of raising."""
    cfg, params, prompts = setup
    srv = make_server(cfg, params, cache_mode="paged", stall_steps=4,
                      default_deadline_s=0.2,
                      faults=FaultPlan(rates={"pool_alloc": 1.0}))
    h = srv.submit(Request(prompt=prompts[0], max_new_tokens=2))
    assert h.result().finish_reason == "deadline"


# ---------------------------------------------------------------------------
# Seeded chaos soak (replayable via REPRO_CHAOS_SEED)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,pfx", [("dense", "off"), ("paged", "off"),
                                      ("paged", "on")])
def test_chaos_soak(setup, mode, pfx):
    """Random fault rates at EVERY site, derived from one printed seed,
    against every cache mode: whatever fires, every request reaches a
    terminal state, failures carry attribution, survivors are bit-identical
    to the fault-free run, and the per-step audit stays clean.  Failures
    print the seed; ``REPRO_CHAOS_SEED=<seed> pytest ...`` replays the
    identical schedule."""
    cfg, params, prompts = setup
    rng = np.random.default_rng((CHAOS_SEED,
                                 zlib.crc32(f"{mode}/{pfx}".encode())))
    rates = {s: round(float(u), 3) for s, u in
             zip(FAULT_SITES, rng.uniform(0.02, 0.2, len(FAULT_SITES)))}
    kw = dict(cache_mode=mode, prefix_cache=pfx)
    if mode == "paged":
        kw["pool_hbm_bytes"] = 8 * page_bytes(cfg)  # real pressure too
    plan = FaultPlan(seed=CHAOS_SEED, rates=rates)
    try:
        clean = make_server(cfg, params, **kw)
        _, base = run_all(clean, prompts)
        srv = make_server(cfg, params, **kw, faults=plan, max_requeues=4,
                          audit_every=1)
        _, res = run_all(srv, prompts)
        for i, r in enumerate(res):
            assert r.finish_reason in ("eos", "length", "error"), i
            if r.finish_reason == "error":
                assert r.error and f"request {i}" in r.error
            else:
                assert r.tokens.tolist() == base[i].tokens.tolist(), i
        assert srv.auditor.report()["clean"], srv.auditor.report()
        assert plan.fired, "soak rates never fired — not a soak"
    except BaseException:
        print(f"\nchaos soak [{mode}/{pfx}] failed; replay with "
              f"REPRO_CHAOS_SEED={CHAOS_SEED}\nplan: {plan!r}\n"
              f"fired: {plan.stats()['fired']}", file=sys.stderr)
        # CI uploads these as the failure artifact (auditor report + the
        # exact schedule); local runs skip the write unless asked.
        rep_dir = os.environ.get("REPRO_CHAOS_REPORT_DIR")
        if rep_dir:
            report = {"seed": CHAOS_SEED, "mode": mode, "prefix": pfx,
                      "plan": plan.stats()}
            if "srv" in locals():
                report["audit"] = srv.auditor.report()
            path = Path(rep_dir) / f"chaos_{mode}_{pfx}.json"
            path.write_text(json.dumps(report, indent=2, default=str))
        raise


def test_chaos_soak_sharded_subprocess():
    """The 4-device leg: forced victimless reclaim on a sharded paged
    arena (2 data shards x 6 pages, prefix sharing on) fails only the
    struck streams; survivors match the clean sharded run bit for bit and
    the auditor holds across every step.  Runs in a subprocess so the
    forced device count cannot leak into this process's jax runtime."""
    prog = textwrap.dedent(f"""
        import dataclasses, json
        import numpy as np, jax
        from repro import api
        from repro.core import pool as blockpool
        from repro.models import model as M, registry
        from repro.launch.mesh import make_serve_mesh
        from repro.serve.faults import FaultPlan

        cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                                  cache_layout="packed", cache_block=8)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        bpp = sum(blockpool.page_nbytes(s, cfg.n_kv_heads,
                                        cfg.resolved_head_dim)
                  for s in M.cache_specs(cfg, 128))
        # Pressure from decode growth (short prompts admit easily, rings
        # grow to ~4 pages each; 2 rows/shard x 6 pages/shard overcommits)
        rng = np.random.default_rng(7)
        work = [(rng.integers(0, cfg.vocab_size, L).astype(np.int32), n)
                for L, n in [(8, 28), (9, 26), (10, 24),
                             (11, 22), (12, 20), (13, 18)]]

        def run(faults, max_requeues, audit_every):
            server = api.serve(cfg, params, max_slots=4, max_seq=128,
                               q_chunk=32, kv_chunk=32, cache_mode="paged",
                               prefix_cache="on",
                               mesh=make_serve_mesh("2,2"),
                               pool_hbm_bytes=12 * bpp,
                               faults=faults, max_requeues=max_requeues,
                               audit_every=audit_every)
            hs = [server.submit(api.Request(prompt=p, max_new_tokens=n))
                  for p, n in work]
            server.run()
            return server, [h.result() for h in hs]

        csrv, base = run(None, 32, 1)
        plan = FaultPlan(seed={CHAOS_SEED},
                         rates={{"reclaim_sweep": 1.0, "prefix_evict": 1.0}})
        fsrv, res = run(plan, 0, 1)
        out = {{
            "clean_reasons": [r.finish_reason for r in base],
            "clean_audit": csrv.auditor.report()["clean"],
            "preemptions": int(csrv.preemptions),
            "reasons": [r.finish_reason for r in res],
            "errors": [r.error for r in res],
            "survivors_match": all(
                res[i].tokens.tolist() == base[i].tokens.tolist()
                for i in range(len(res))
                if res[i].finish_reason != "error"),
            "audit": fsrv.auditor.report(),
            "fired": len(plan.fired),
            "failures": fsrv.stats()["lifecycle"]["failures"],
        }}
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(r in ("eos", "length") for r in res["clean_reasons"]), res
    assert res["clean_audit"] and res["preemptions"] > 0, res
    failed = [i for i, r in enumerate(res["reasons"]) if r == "error"]
    assert failed and len(failed) < len(res["reasons"]), res
    for i in failed:
        assert "pool exhausted with no reclaimable pages" in res["errors"][i]
    assert res["survivors_match"], res
    assert res["audit"]["clean"], res["audit"]
    assert res["fired"] > 0 and res["failures"] == len(failed), res


# ---------------------------------------------------------------------------
# The auditor catches real corruption (it is not a rubber stamp)
# ---------------------------------------------------------------------------


def test_auditor_detects_seeded_corruption(setup):
    """Sabotage a live server's bookkeeping in the ways the auditor
    claims to cover and verify each is reported."""
    cfg, params, prompts = setup
    srv = make_server(cfg, params, cache_mode="paged", prefix_cache="on",
                      audit_every=1)
    hs = [srv.submit(Request(prompt=p, max_new_tokens=n))
          for p, n in zip(prompts, NEWS)]
    srv.step()
    srv.step()
    assert srv.auditor.audit() == []  # clean mid-flight
    # 1. leak a refcount: retain a live page nobody else references
    live = next(iter(srv.pool._live))
    srv.pool.retain([live])
    bad = srv.auditor.audit()
    assert any("refcount" in b for b in bad), bad
    srv.pool.release([live])
    assert srv.auditor.audit() == []
    # 2. host/device divergence: flip one host page-table entry
    row = next(r for r, s in enumerate(srv._slots) if s is not None)
    slot = int(np.argmax(srv._pt_host[row] >= 0))
    keep = srv._pt_host[row, slot]
    srv._pt_host[row, slot] = -1
    bad = srv.auditor.audit()
    assert any("device page table" in b or "refcount" in b for b in bad), bad
    srv._pt_host[row, slot] = keep
    # 3. a finished handle left scheduled
    h = srv._slots[row]
    h._finish = "length"
    with pytest.raises(InvariantViolation, match="still scheduled"):
        srv.auditor.check()
    h._finish = None
    srv.run()
    for h in hs:
        h.result()
    assert srv.auditor.audit() == []
