"""End-to-end codec: ratio ordering (the paper's central claim), metadata
accounting, full encode/decode roundtrips through both entropy paths."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.codec import KVCompCodec, RatioReport


@pytest.fixture(scope="module")
def kv_data():
    # LM-like KV statistics: per-channel location/scale with HEAVY TAILS
    # (student-t) — outliers stretch each unit's min/max so the quantized
    # code histogram concentrates on few levels, exactly the paper's Fig. 3.
    rng = np.random.default_rng(0)
    mu = rng.normal(size=(1, 8, 64))
    sc = rng.uniform(0.2, 2.0, (1, 1, 64))
    k = (mu + sc * rng.standard_t(3, size=(512, 8, 64))).astype(np.float32)
    v = (0.5 * rng.standard_t(3, size=(512, 8, 64))).astype(np.float32)
    return jnp.asarray(k), jnp.asarray(v)


@pytest.fixture(scope="module")
def codec(kv_data):
    c = KVCompCodec(quant.QuantConfig(block_size=64, rel_scale_k=0.05,
                                      rel_scale_v=0.15))
    c.fit(*kv_data)
    return c


def test_ratio_ordering_huffman_beats_packed_beats_raw(codec, kv_data):
    k, _ = kv_data
    qk = codec.quantize_k(k)
    r_huff = codec.report_k(qk, "huffman")
    r_pack = codec.report_k(qk, "packed")
    assert r_huff.ratio > r_pack.ratio > 1.0
    # Huffman payload beats 8-bit raw codes
    assert r_huff.payload_bits < qk.codes.size * 8


def test_kvcomp_beats_kivi_at_iso_accuracy(kv_data):
    """The paper's headline: at matched accuracy (same quantizer error),
    entropy coding adds ratio that fixed-width KIVI cannot."""
    k, v = kv_data
    # KIVI-4bit ≈ 16 levels; KVComp rel scale with same worst-case step
    # over the same units -> comparable error, then Huffman adds ratio.
    cfg = quant.QuantConfig(block_size=64, rel_scale_k=1 / 15, rel_scale_v=1 / 15,
                            kivi_bits=4)
    codec = KVCompCodec(cfg)
    codec.fit(k, v)
    qk = codec.quantize_k(k)
    r_huff = codec.report_k(qk, "huffman")
    q_kivi = quant.kivi_quantize_k(k, 4, 64)
    r_kivi = RatioReport(
        n_values=int(q_kivi.codes.size),
        payload_bits=int(q_kivi.codes.size) * 4,
        scale_bits=q_kivi.meta_bits, stream_meta_bits=0,
        offset_meta_bits=0, codebook_bits=0)
    err_kvcomp = float(jnp.max(jnp.abs(qk.dequantize().reshape(k.shape) - k)))
    err_kivi = float(jnp.max(jnp.abs(q_kivi.dequantize().reshape(k.shape) - k)))
    assert err_kvcomp <= err_kivi * 1.05  # iso-accuracy (same step bound)
    assert r_huff.ratio > r_kivi.ratio    # strictly better ratio


def test_metadata_accounting_matches_paper_scale(codec, kv_data):
    """Paper §3.2.2: thread metadata ≈ 1/128 of original size."""
    k, _ = kv_data
    qk = codec.quantize_k(k)
    r = codec.report_k(qk, "huffman")
    original_bits = r.n_values * 16
    assert r.stream_meta_bits / original_bits == pytest.approx(1 / 64, rel=0.01)
    # (one u16 per head_dim=64 stream of 16-bit values -> 16/(64*16) = 1/64;
    #  the paper's 1/128 assumes head_dim=128)
    assert r.offset_meta_bits < r.stream_meta_bits
    assert r.codebook_bits == 256 * 4


def test_full_huffman_roundtrip(codec, kv_data):
    k, _ = kv_data
    qk = codec.quantize_k(k)
    payload, nbits, shape = codec.encode_huffman(qk, "k")
    codes = codec.decode_huffman(payload, nbits, shape, "k")
    assert (np.asarray(codes) == np.asarray(qk.codes)).all()


def test_full_packed_roundtrip(codec, kv_data):
    k, _ = kv_data
    qk = codec.quantize_k(k)
    packed = codec.encode_packed(qk)
    codes = codec.decode_packed(packed, qk.codes.shape)
    assert (np.asarray(codes) == np.asarray(qk.codes)).all()


def test_v_reports(codec, kv_data):
    _, v = kv_data
    qv = codec.quantize_v(v)
    r = codec.report_v(qv, "huffman")
    assert r.ratio > 2.0  # rel 0.15 -> ~3 bits payload + meta, vs 16-bit raw
    assert r.bits_per_value < 8
