"""Compressed-page prefix cache (DESIGN.md §11).

Four layers of guarantees:

* refcount invariants — a page is never reissued while any reference holds
  it, double-release raises, retain/release bracket exactly (hypothesis
  property tests over a shadow refcount model);
* index semantics — longest-prefix lookup is block-aligned and exact
  (token-byte keys, no hash aliasing), LRU eviction only reclaims leaves
  and respects the protect set;
* serving semantics — sharing on vs noshare is bit-identical at the greedy
  tokens while actually reusing cached blocks; a preempted request resumes
  from cached pages (no prompt replay) and still matches the ample-pool
  run; copy-on-write never leaves a shared page as any row's writable
  flush target (checked on every ensure-pages sweep under a sliding-window
  ring that wraps onto shared prefix pages);
* plumbing — prefix mode demands a paged cache, and api.serve threads the
  mode through to the scheduler and its stats.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import api
from repro.core import pool
from repro.models import model as M
from repro.models import registry
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import Request, Server, ServerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("yi_6b")
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pool_page_bytes(cfg, max_seq=256):
    specs = M.cache_specs(cfg, max_seq)
    return sum(pool.page_nbytes(s, cfg.n_kv_heads, cfg.resolved_head_dim)
               for s in specs), specs[0]


def _serve(cfg, params, mode, pool_bytes=None, max_slots=2, max_seq=256):
    return Server(cfg, params,
                  ServerConfig(max_slots=max_slots, max_seq=max_seq,
                               cache_mode="paged", pool_hbm_bytes=pool_bytes,
                               prefix_cache=mode),
                  q_chunk=32, kv_chunk=32)


# ---------------------------------------------------------------------------
# Refcount invariants (hypothesis property tests)
# ---------------------------------------------------------------------------


def test_refcount_lifecycle_basics():
    p = pool.PagedBlockPool(4, (64,))
    a = p.alloc(2)
    assert all(p.refcount(x) == 1 for x in a)
    p.retain(a)
    assert all(p.refcount(x) == 2 for x in a)
    assert p.release(a) == []          # still referenced: nothing freed
    assert p.free_pages == 2
    assert sorted(p.release(a)) == sorted(a)  # last ref: both freed
    assert p.free_pages == 4
    with pytest.raises(RuntimeError, match="not live"):
        p.release(a[:1])
    assert p.refcount(a[0]) == 0       # dead pages read as zero


def test_refcount_property_no_reissue_while_referenced():
    """Whatever interleaving of alloc / retain / release happens, a page
    with a positive refcount is never handed out by alloc again, pages only
    rejoin the free list at refcount zero, and a shadow model of the counts
    stays in exact agreement."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5)),
                    max_size=80))
    def run(ops_):
        p = pool.PagedBlockPool(8, (32,))
        refs: dict[int, int] = {}  # shadow model
        for kind, n in ops_:
            live = sorted(refs)
            if kind == 0:  # alloc
                if n <= p.free_pages:
                    got = p.alloc(n)
                    assert not (set(got) & set(live)), \
                        "alloc reissued a page that still has references"
                    refs.update((g, 1) for g in got)
                else:
                    with pytest.raises(pool.PoolExhausted):
                        p.alloc(n)
            elif kind == 1 and live:  # retain some live pages
                take = live[: max(n, 1)]
                p.retain(take)
                for t in take:
                    refs[t] += 1
            elif kind == 2 and live:  # release some live pages
                take = live[: max(n, 1)]
                freed = p.release(take)
                expect_freed = []
                for t in take:
                    refs[t] -= 1
                    if refs[t] == 0:
                        del refs[t]
                        expect_freed.append(t)
                assert sorted(freed) == sorted(expect_freed)
            assert {x: p.refcount(x) for x in refs} == refs
            assert p.live_pages == len(refs)
            assert p.free_pages == p.n_pages - len(refs)
            st_ = p.stats()
            assert st_["refs_total"] == sum(refs.values())
            assert st_["pages_shared"] == sum(v > 1 for v in refs.values())

    run()


def test_release_after_double_release_model():
    """The satellite contract verbatim: double-release raises even when the
    page was re-allocated in between (the new owner's count is 1, and the
    stale releaser going through would corrupt it) — release only balances
    retain/alloc brackets that are actually open."""
    p = pool.PagedBlockPool(1, (16,))
    (a,) = p.alloc(1)
    p.release([a])
    (b,) = p.alloc(1)
    assert b == a  # the only page comes back
    p.release([b])
    with pytest.raises(RuntimeError, match="not live"):
        p.release([b])


# ---------------------------------------------------------------------------
# PrefixIndex semantics
# ---------------------------------------------------------------------------


def test_prefix_index_block_aligned_exact_lookup():
    p = pool.PagedBlockPool(16, (16,))
    idx = PrefixIndex(block_size=4)
    toks = np.arange(12, dtype=np.int32)  # 3 full blocks
    pages = p.alloc(3)
    assert idx.insert(toks, pages, p) == 3
    assert all(p.refcount(g) == 2 for g in pages)  # index holds its own ref

    assert idx.lookup(toks, 3) == pages
    assert idx.lookup(toks, 2) == pages[:2]          # cap respected
    assert idx.lookup(toks[:8], 3) == pages[:2]      # shorter prefix
    assert idx.lookup(toks[:7], 3) == pages[:1]      # partial block ignored
    div = toks.copy()
    div[5] = 99                                      # diverge inside block 1
    assert idx.lookup(div, 3) == pages[:1]
    assert idx.lookup(np.arange(100, 112, dtype=np.int32), 3) == []

    # re-inserting the same tokens keeps the ORIGINAL pages (first writer
    # wins — chunked admission makes the contents identical anyway)
    other = p.alloc(3)
    assert idx.insert(toks, other, p) == 0
    assert idx.lookup(toks, 3) == pages


def test_prefix_index_lru_leaf_eviction_and_protect():
    p = pool.PagedBlockPool(8, (16,))
    idx = PrefixIndex(block_size=4)
    a = np.arange(8, dtype=np.int32)
    b = np.concatenate([a[:4], np.arange(50, 54, dtype=np.int32)])
    pa, pb = p.alloc(2), p.alloc(2)
    idx.insert(a, pa, p)
    idx.insert(b, pb, p)
    p.release(pa), p.release(pb)  # only the index holds them now
    assert p.free_pages == 8 - 3  # shared root block + two leaves
    idx.lookup(a, 2)  # MRU-stamp chain a: chain b's leaf is now coldest

    assert idx.evict(p, need_free=6) >= 1
    assert p.free_pages >= 6
    assert idx.lookup(a, 2) == pa  # the hot chain survived
    assert idx.lookup(b, 2) == pa[:1]  # b's leaf is gone, shared root stays

    # protect pins pages even when they are the LRU choice
    freed = idx.evict(p, need_free=8, protect=pa)
    assert p.refcount(pa[0]) >= 1 and p.refcount(pa[1]) >= 1
    assert idx.lookup(a, 2) == pa


def test_prefix_mode_requires_paged():
    cfg = registry.get_smoke_config("yi_6b")
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        Server(cfg, params, ServerConfig(max_slots=2, max_seq=256,
                                         prefix_cache="on"))
    with pytest.raises(ValueError, match="prefix_cache"):
        Server(cfg, params, ServerConfig(max_slots=2, max_seq=256,
                                         cache_mode="paged",
                                         pool_hbm_bytes=1 << 24,
                                         prefix_cache="sometimes"))


# ---------------------------------------------------------------------------
# Serving semantics
# ---------------------------------------------------------------------------


def test_sharing_on_vs_noshare_bit_identical_with_real_reuse(setup):
    """The §11 acceptance contract: same workload, same paged config —
    prefix_cache="on" must reuse cached blocks (reused_tokens > 0, fewer
    prefill tokens) while every greedy token stays bit-identical to the
    noshare baseline."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)  # 3 blocks
    reqs = [Request(prompt=np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, 1 + i).astype(np.int32)]),
                    max_new_tokens=6) for i in range(3)]

    outs, stats = {}, {}
    for mode in ("noshare", "on"):
        srv = _serve(cfg, params, mode)
        hs = [srv.submit(r) for r in reqs]
        srv.run()
        outs[mode] = [h.result().tokens.tolist() for h in hs]
        stats[mode] = srv.stats()
    assert outs["on"] == outs["noshare"]
    px = stats["on"]["prefix"]
    assert px["reused_tokens"] >= 2 * len(shared)  # req 2 and 3 hit
    assert px["hits"] >= 2 and px["hit_rate"] > 0
    assert px["prefill_tokens"] < stats["noshare"]["prefix"]["prefill_tokens"]
    # retirement dropped the rows' refs; only the index holds pages now
    assert stats["on"]["pool"]["refs_total"] == stats["on"]["prefix"]["index"]["blocks"]


def test_preempt_resumes_from_cached_pages(setup):
    """A pool too small for the admitted load forces a preemption; in
    prefix mode the victim's flushed blocks park in the index and its
    generated tokens survive, so re-admission restores from cached pages
    instead of replaying the prompt — and the tokens still match the
    ample-pool run bit-exactly."""
    cfg, params = setup
    page_b, _ = _pool_page_bytes(cfg)
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    reqs = [Request(prompt=np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, 1 + i).astype(np.int32)]),
                    max_new_tokens=24) for i in range(2)]

    ample = _serve(cfg, params, "on")
    ref = [ample.submit(r) for r in reqs]
    ample.run()
    ref_toks = [h.result().tokens.tolist() for h in ref]
    assert ample.preemptions == 0

    tiny = _serve(cfg, params, "on", pool_bytes=6 * page_b)
    hs = [tiny.submit(r) for r in reqs]
    tiny.run()
    px = tiny.stats()["prefix"]
    assert tiny.preemptions >= 1, "workload failed to force a preemption"
    assert px["resumes"] >= 1
    assert px["resume_reused_blocks"] >= 1, "resume replayed the prompt"
    assert [h.result().tokens.tolist() for h in hs] == ref_toks


class _CowAuditServer(Server):
    """Asserts the CoW invariant on every flush sweep: once _ensure_pages
    returns, every row flushing on the next step targets a page it owns
    EXCLUSIVELY — a shared page (prefix index or sibling row) must never be
    any row's writable tail."""

    audited = 0

    def _ensure_pages(self):
        super()._ensure_pages()
        T, nb = self._spec0.block_size, self._spec0.n_blocks
        for row, h in enumerate(self._slots):
            if h is None or (int(self._pos[row]) + 1) % T:
                continue
            slot = ((int(self._pos[row]) + 1) // T - 1) % nb
            page = int(self._pt_host[row, slot])
            assert page >= 0, "flush target unassigned after ensure sweep"
            assert self.pool.refcount(page) == 1, \
                f"row {row} would flush into shared page {page}"
            type(self).audited += 1


def test_cow_never_aliases_shared_page_into_writable_tail(setup):
    """Sliding-window ring wrap drives rows straight onto their spliced
    (shared) prefix pages — the audit subclass proves every flush lands on
    an exclusively-owned page, and the outputs still match noshare."""
    cfg, params = setup
    cfg = dataclasses.replace(cfg, sliding_window=16)
    params2, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)  # full window
    reqs = [Request(prompt=prompt, max_new_tokens=20) for _ in range(2)]

    _CowAuditServer.audited = 0
    srv = _CowAuditServer(cfg, params2,
                          ServerConfig(max_slots=2, max_seq=256,
                                       cache_mode="paged", prefix_cache="on"),
                          q_chunk=32, kv_chunk=32)
    hs = [srv.submit(r) for r in reqs]
    srv.run()
    on = [h.result().tokens.tolist() for h in hs]
    px = srv.stats()["prefix"]
    assert _CowAuditServer.audited > 0, "no flush was audited"
    assert px["cow_breaks"] >= 1, "ring never wrapped onto a shared page"
    assert on[0] == on[1]  # identical requests, identical greedy tokens

    base = _serve(cfg, params2, "noshare")
    ns = [base.submit(r) for r in reqs]
    base.run()
    assert [h.result().tokens.tolist() for h in ns] == on


def test_api_serve_threads_prefix_cache(setup):
    cfg, params = setup
    srv = api.serve(cfg, params, max_slots=2, max_seq=256,
                    cache_mode="paged", prefix_cache="on",
                    q_chunk=32, kv_chunk=32)
    h = srv.submit(api.Request(np.arange(1, 10, dtype=np.int32),
                               max_new_tokens=3))
    h.result()
    st = srv.stats()
    assert st["prefix"]["mode"] == "on"
    assert {"hit_rate", "reused_tokens", "cow_breaks",
            "resumes"} <= set(st["prefix"])
    assert "refs_total" in st["pool"] and "pages_shared" in st["pool"]


def test_paged_submit_rejection_names_both_knobs(setup):
    """Satellite 6: the oversized-request error must point at BOTH the
    api.serve kwarg and the CLI flag."""
    cfg, params = setup
    page_b, _ = _pool_page_bytes(cfg)
    srv = _serve(cfg, params, "off", pool_bytes=3 * page_b)
    with pytest.raises(ValueError) as ei:
        srv.submit(Request(prompt=np.zeros(64, np.int32), max_new_tokens=32))
    assert "pool_hbm_bytes=" in str(ei.value)
    assert "--pool-bytes" in str(ei.value)


@pytest.mark.parametrize("share", ["on", "noshare"])
def test_chunked_vs_solo_admission_bit_identity_prefix(setup, share):
    """Bit-identity matrix, prefix legs: interleaved chunked admission over
    the radix index (hits splice cached pages into a mid-flight task) must
    match the blocking solo drain token for token, sharing on or off."""
    cfg, params = setup
    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    reqs = [Request(prompt=np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, 3 + i).astype(np.int32)]),
                    max_new_tokens=5) for i in range(3)]
    outs = {}
    for mode in ("chunked", "solo"):
        srv = Server(cfg, params,
                     ServerConfig(max_slots=2, max_seq=256,
                                  cache_mode="paged", prefix_cache=share,
                                  prefill_mode=mode,
                                  prefill_chunk_tokens=8),
                     q_chunk=32, kv_chunk=32)
        hs = [srv.submit(r) for r in reqs]
        srv.run()
        outs[mode] = [h.result().tokens.tolist() for h in hs]
        if share == "on":
            assert srv.stats()["prefix"]["hits"] >= 1, mode
    assert outs["chunked"] == outs["solo"]
