"""Serving observability (DESIGN.md §14): metrics registry, scheduler event
trace, and the stats()/export surfaces built on them.

The load-bearing contracts:

* ``Server.stats()`` is ONE schema — the key tree depends only on
  (cache_mode, prefix_cache), never on the mesh (the sharded leg runs in a
  subprocess with a forced 4-device count and must produce the identical
  tree).
* Trace-reconstructed per-request timings equal the ``Result`` fields
  EXACTLY (float-for-float): token events reuse the same monotonic stamps.
* ``trace="off"`` records nothing and adds no device dispatches — greedy
  outputs are bit-identical to a traced run.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import EventTrace
from repro.models import model as M
from repro.models import registry
from repro.serve.scheduler import Request, Server, ServerConfig

SRC = str(Path(__file__).resolve().parents[1] / "src")

LENS = (7, 13, 19, 26)
NEWS = (3, 6, 4, 5)


# ---------------------------------------------------------------------------
# Metrics primitives (pure host, no jax)
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge()
    g.set(3.0)
    g.set_max(2.0)
    assert g.value == 3.0
    g.set_max(7.5)
    assert g.value == 7.5


def test_histogram_observe_and_quantiles():
    h = Histogram()
    vals = [0.001, 0.002, 0.004, 0.008, 0.016, 0.032]
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(sum(vals))
    assert h.min == min(vals) and h.max == max(vals)
    # quantiles: monotone, clamped to the observed range
    q = [h.quantile(p) for p in (0.0, 0.25, 0.5, 0.9, 1.0)]
    assert all(a <= b for a, b in zip(q, q[1:]))
    assert min(vals) <= q[0] and q[-1] <= max(vals)
    snap = h.snapshot()
    assert set(snap) == {"count", "sum", "mean", "min", "max", "p50", "p99"}
    assert snap["mean"] == pytest.approx(sum(vals) / len(vals))
    # empty histogram: all-zero snapshot, no division blowups
    assert Histogram().snapshot()["count"] == 0
    assert Histogram().quantile(0.5) == 0.0


def test_registry_snapshot_nesting_and_types():
    reg = MetricsRegistry()
    reg.counter("serve.preemptions").inc(2)
    reg.gauge("pool.shard0.high_water_pages").set(7)
    reg.histogram("serve.ttft_s").observe(0.01)
    snap = reg.snapshot()
    assert snap["serve"]["preemptions"] == 2
    assert snap["pool"]["shard0"]["high_water_pages"] == 7
    assert snap["serve"]["ttft_s"]["count"] == 1
    # same name + same type returns the same object; a type clash raises
    assert reg.counter("serve.preemptions").value == 2
    with pytest.raises(TypeError):
        reg.gauge("serve.preemptions")
    assert "serve.preemptions" in reg
    assert reg.get("nope") is None


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("serve.preemptions").inc()
    reg.histogram("serve.ttft_s").observe(0.01)
    text = reg.prometheus_text()
    assert "# TYPE kvcomp_serve_preemptions counter" in text
    assert "kvcomp_serve_preemptions 1" in text
    assert "# TYPE kvcomp_serve_ttft_s histogram" in text
    assert 'kvcomp_serve_ttft_s_bucket{le="+Inf"} 1' in text
    assert "kvcomp_serve_ttft_s_count 1" in text


# ---------------------------------------------------------------------------
# EventTrace primitives
# ---------------------------------------------------------------------------


def test_trace_levels_and_ring_drop():
    with pytest.raises(ValueError):
        EventTrace("verbose")
    tr = EventTrace("events", capacity=4)
    assert tr.enabled and not tr.full
    for i in range(10):
        tr.emit("token", req=0, t=float(i), index=i)
    assert len(tr.events) == 4
    assert tr.emitted == 10 and tr.dropped == 6
    off = EventTrace("off")
    assert not off.enabled


def test_request_timings_reconstruction_synthetic():
    tr = EventTrace("events")
    tr.emit("submit", req=3, t=1.0)
    tr.emit("prefill_start", req=3, t=1.5, row=0)
    tr.emit("token", req=3, t=2.0, index=0)
    tr.emit("token", req=3, t=2.25, index=1)
    tr.emit("token", req=3, t=2.25, index=1)  # replay: same index ignored
    tr.emit("retire", req=3, t=2.3, reason="length")
    tim = tr.request_timings()[3]
    assert tim["submit"] == 1.0 and tim["first_work"] == 1.5
    assert tim["token_times"] == (2.0, 2.25)
    assert tim["ttft_s"] == 1.0
    assert tim["retired"] and tim["reason"] == "length"


def test_chrome_export_structure_synthetic():
    tr = EventTrace("full")
    tr.emit("submit", req=0, t=1.0)
    tr.emit("prefill_start", req=0, t=1.2, row=0)
    tr.emit("prefill_chunk", req=0, t=1.25, dur=0.05, row=0, pos=0, tokens=8)
    tr.emit("token", req=0, t=1.5, index=0)
    tr.emit("retire", req=0, t=1.6, reason="length")
    tr.emit("decode_step", t=1.4, dur=0.01, rows=1)
    doc = tr.to_chrome()
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} == {"M", "X", "i"}
    names = {e["name"] for e in evs}
    # metadata tracks + raw events + synthesized queue/decode spans
    assert {"process_name", "thread_name", "prefill_chunk", "decode_step",
            "queue", "decode"} <= names
    track = [e for e in evs if e["name"] == "thread_name"
             and e["tid"] == 1][0]
    assert track["args"]["name"] == "req 0"
    queue = [e for e in evs if e["name"] == "queue"][0]
    assert queue["ts"] == pytest.approx(1.0e6)
    assert queue["dur"] == pytest.approx(0.2e6)
    json.dumps(doc)  # must be serializable as-is


# ---------------------------------------------------------------------------
# Server integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("yi_6b")
    cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate([
        shared, rng.integers(0, cfg.vocab_size, L).astype(np.int32)])
        for L in LENS]
    return cfg, params, prompts


def _run(cfg, params, prompts, **kw):
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=128, **kw),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    server.run()
    return server, handles, [h.result() for h in handles]


@pytest.fixture(scope="module")
def traced(setup):
    """One paged + prefix-sharing server run under trace='full'."""
    cfg, params, prompts = setup
    return _run(cfg, params, prompts, cache_mode="paged", prefix_cache="on",
                trace="full")


def key_tree(d):
    """Shape of a stats tree: nested keys with list lengths normalized (a
    per_shard list of 1 and of 4 have the same schema)."""
    if isinstance(d, dict):
        return {k: key_tree(v) for k, v in sorted(d.items())}
    if isinstance(d, list):
        return [key_tree(d[0])] if d else []
    return "."


LAT_KEYS = {"count", "sum", "mean", "min", "max", "p50", "p99"}


def test_stats_schema_across_modes(setup):
    """The documented tree: key structure is a pure function of
    (cache_mode, prefix_cache) — latency/trace/shards always present,
    pool (aggregate + per_shard) in paged mode, prefix when enabled."""
    cfg, params, prompts = setup
    combos = [("dense", "off"), ("paged", "off"),
              ("paged", "on"), ("paged", "noshare")]
    stats = {}
    for mode, pfx in combos:
        server, _, results = _run(cfg, params, prompts, cache_mode=mode,
                                  prefix_cache=pfx)
        assert all(len(r.tokens) for r in results)
        stats[(mode, pfx)] = server.stats()
    for (mode, pfx), st in stats.items():
        base = {"cache_mode", "active", "pending", "preemptions",
                "prefill", "latency", "trace", "shards", "lifecycle"}
        want = base | ({"pool"} if mode == "paged" else set())
        want |= {"prefix"} if pfx != "off" else set()
        assert set(st) == want, (mode, pfx)
        assert st["cache_mode"] == mode
        for h in ("ttft_s", "itl_s", "queue_wait_s"):
            assert set(st["latency"][h]) == LAT_KEYS
        assert st["latency"]["ttft_s"]["count"] == len(prompts)
        assert set(st["trace"]) == {"level", "events", "dropped"}
        sh = st["shards"]
        assert sh["n_data"] == 1 and len(sh["per_shard"]) == 1
        for p in sh["per_shard"]:
            want_sh = {"preemptions"} | (
                {"pages_live", "pages_free", "high_water_pages"}
                if mode == "paged" else set())
            assert set(p) == want_sh
        if mode == "paged":
            pl = st["pool"]
            assert {"pages_total", "pages_live", "pages_free",
                    "high_water_pages", "alloc_pages", "freed_pages",
                    "per_shard"} <= set(pl)
            assert len(pl["per_shard"]) == 1
    # identical paged trees whether sharing is on or merely accounted
    t_on = key_tree(stats[("paged", "on")])
    t_no = key_tree(stats[("paged", "noshare")])
    t_on["prefix"].pop("index")  # noshare keeps no radix index
    assert t_on == t_no


def test_stats_schema_sharded_equals_unsharded(setup):
    """Mesh-invariance: a 4-device paged server's stats() has the IDENTICAL
    key tree as the single-device paged server (per_shard just gets more
    entries).  The subprocess forces a fake 4-device CPU count."""
    cfg, params, prompts = setup
    server, _, _ = _run(cfg, params, prompts, cache_mode="paged")
    local = key_tree(server.stats())
    prog = textwrap.dedent("""
        import dataclasses, json
        import jax, numpy as np
        from repro.launch.mesh import make_serve_mesh
        from repro.models import model as M
        from repro.models import registry
        from repro.serve.scheduler import Request, Server, ServerConfig

        def key_tree(d):
            if isinstance(d, dict):
                return {k: key_tree(v) for k, v in sorted(d.items())}
            if isinstance(d, list):
                return [key_tree(d[0])] if d else []
            return "."

        LENS, NEWS = (7, 13, 19, 26), (3, 6, 4, 5)
        cfg = registry.get_smoke_config("yi_6b")
        cfg = dataclasses.replace(cfg, cache_layout="packed", cache_block=8)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        prompts = [np.concatenate([
            shared, rng.integers(0, cfg.vocab_size, L).astype(np.int32)])
            for L in LENS]
        server = Server(cfg, params,
                        ServerConfig(max_slots=4, max_seq=128,
                                     cache_mode="paged",
                                     mesh=make_serve_mesh("4,1")),
                        q_chunk=32, kv_chunk=32)
        for p, n in zip(prompts, NEWS):
            server.submit(Request(prompt=p, max_new_tokens=n))
        server.run()
        st = server.stats()
        print(json.dumps({"tree": key_tree(st),
                          "n_data": st["shards"]["n_data"],
                          "n_per_shard": len(st["shards"]["per_shard"]),
                          "n_pool_shards": len(st["pool"]["per_shard"])}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_data"] == 4
    assert res["n_per_shard"] == 4 and res["n_pool_shards"] == 4
    assert res["tree"] == local


def test_trace_timings_equal_results_exactly(traced):
    """The identity contract: reconstructed token_times / TTFT are the SAME
    floats Result carries — not approximately, exactly."""
    server, handles, results = traced
    tim = server.trace.request_timings()
    assert server.stats()["trace"]["dropped"] == 0
    for h, r in zip(handles, results):
        t = tim[h.id]
        assert t["token_times"] == r.token_times
        assert t["ttft_s"] == r.ttft_s
        assert t["retired"] and t["reason"] == r.finish_reason


def test_trace_full_records_scheduler_vocabulary(traced):
    server, handles, results = traced
    kinds = {e.kind for e in server.trace.events}
    assert {"submit", "prefill_start", "prefill_chunk", "prefill_finish",
            "token", "retire", "page_assign", "prefix_hit",
            "decode_step"} <= kinds
    # every token of every result is in the ring (small run, no wrap)
    n_tok = sum(1 for e in server.trace.events if e.kind == "token")
    assert n_tok == sum(len(r.tokens) for r in results)


def test_chrome_export_from_server(traced, tmp_path):
    server, handles, _ = traced
    path = tmp_path / "trace.json"
    server.trace.write_chrome(path)
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    track_names = {e["args"]["name"] for e in evs
                   if e["name"] == "thread_name"}
    assert {"scheduler"} | {f"req {h.id}" for h in handles} <= track_names
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] in ("X", "i"):
            assert "ts" in e


def test_shutdown_writes_exports(traced, tmp_path):
    server, _, _ = traced
    mpath, tpath = tmp_path / "metrics.json", tmp_path / "trace.json"
    snap = server.shutdown(metrics_out=mpath, trace_out=tpath)
    disk = json.loads(mpath.read_text())
    assert set(disk) == set(snap) == {"stats", "metrics"}
    assert disk["stats"]["cache_mode"] == "paged"
    assert disk["metrics"]["serve"]["ttft_s"]["count"] > 0
    prom = mpath.with_suffix(".prom").read_text()
    assert "# TYPE kvcomp_serve_preemptions counter" in prom
    assert json.loads(tpath.read_text())["traceEvents"]


def test_bench_columns_schema(traced):
    server, _, _ = traced
    cols = obs.bench_columns(server)
    assert tuple(cols) == obs.BENCH_COLUMNS
    assert cols["ttft_p50_s"] > 0 and cols["itl_p50_s"] >= 0


def test_format_snapshot_renders_all_sections(traced):
    server, _, _ = traced
    text = obs.format_snapshot(server.stats())
    for frag in ("serve[paged]", "lifecycle:", "prefill[", "latency:",
                 "pool:", "shards:", "prefix[on]", "trace[full]"):
        assert frag in text, frag


# The Server's jitted device entry points — everything a step can dispatch.
DISPATCH_ATTRS = ("_prefill", "_decode", "_insert", "_assign", "_clear",
                  "_chunk", "_chunk_scan", "_fresh", "_chunk_paged",
                  "_chunk_paged_scan", "_finish_paged", "_gather")


def _count_dispatches(server) -> dict:
    counts = {"n": 0}
    for name in DISPATCH_ATTRS:
        fn = getattr(server, name, None)
        if fn is None or not callable(fn):
            continue

        def wrap(f):
            def g(*a, **k):
                counts["n"] += 1
                return f(*a, **k)
            return g

        setattr(server, name, wrap(fn))
    return counts


def test_trace_off_zero_events_zero_extra_dispatches(setup):
    """trace='off' must cost nothing: no events, the same number of device
    dispatches as a fully traced run, and bit-identical greedy tokens."""
    cfg, params, prompts = setup
    runs = {}
    for level in ("off", "full"):
        server = Server(cfg, params,
                        ServerConfig(max_slots=2, max_seq=128,
                                     cache_mode="paged", prefix_cache="on",
                                     trace=level),
                        q_chunk=32, kv_chunk=32)
        counts = _count_dispatches(server)
        handles = [server.submit(Request(prompt=p, max_new_tokens=n))
                   for p, n in zip(prompts, NEWS)]
        server.run()
        runs[level] = (server, counts["n"],
                       [h.result().tokens.tolist() for h in handles])
    off_server, off_n, off_toks = runs["off"]
    full_server, full_n, full_toks = runs["full"]
    assert len(off_server.trace.events) == 0
    assert off_server.trace.emitted == 0
    assert off_server.stats()["trace"] == {"level": "off", "events": 0,
                                           "dropped": 0}
    assert len(full_server.trace.events) > 0
    assert off_n == full_n
    assert off_toks == full_toks
