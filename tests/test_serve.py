"""Serving engine: greedy decode vs step-by-step reference; layout memory."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models import registry
from repro.serve.engine import Engine, EngineConfig, Request, cache_memory_report


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                              cache_layout="raw")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _manual_greedy(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt)[None, :]
    lg, state = M.prefill(params, cfg, {"tokens": toks}, 256,
                          q_chunk=32, kv_chunk=32)
    cur = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
    out = [int(cur[0])]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, state = M.decode_step(params, cfg, cur, jnp.asarray(pos, jnp.int32), state)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out.append(int(cur[0]))
        pos += 1
    return out


def test_engine_matches_manual_greedy(setup, rng):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(bucket=32, max_batch=2, max_seq=256),
                 q_chunk=32, kv_chunk=32)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    res = eng.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    expect = _manual_greedy(cfg, params, prompt, 6)
    assert res.tokens.tolist() == expect


def test_engine_batches_independent_requests(setup, rng):
    """Batched decoding must equal per-request decoding (same lengths)."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(bucket=32, max_batch=4, max_seq=256),
                 q_chunk=32, kv_chunk=32)
    prompts = [rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(3)]
    batched = eng.generate([Request(prompt=p, max_new_tokens=4) for p in prompts])
    for p, r in zip(prompts, batched):
        solo = eng.generate([Request(prompt=p, max_new_tokens=4)])[0]
        assert r.tokens.tolist() == solo.tokens.tolist()


def test_engine_bucketing(setup, rng):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(bucket=16, max_batch=8, max_seq=256),
                 q_chunk=16, kv_chunk=16)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=2)
            for L in (10, 16, 20, 31)]
    res = eng.generate(reqs)
    assert all(r is not None and len(r.tokens) == 2 for r in res)


def test_cache_memory_report_orders_layouts(rng):
    base = registry.get_smoke_config("yi_6b")
    sizes = {}
    for layout in ("raw", "packed", "kivi"):
        cfg = dataclasses.replace(base, cache_layout=layout)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)))
        _, state = M.prefill(params, cfg, {"tokens": toks}, 128,
                             q_chunk=32, kv_chunk=32)
        sizes[layout] = cache_memory_report(cfg, state)["kv_bytes"]
    assert sizes["packed"] < sizes["raw"]
    assert sizes["kivi"] < sizes["packed"]  # 2-bit beats 5/3-bit on size
