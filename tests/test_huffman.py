"""Canonical Huffman: codebook invariants + exact roundtrips (paper §3.1.2,
§3.3.1) across numpy-oracle and vectorized-JAX implementations."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.core import huffman


def _random_codes(rng, skew, shape):
    vals = np.clip(np.round(rng.normal(8, skew, size=shape)), 0, 255)
    return vals.astype(np.uint8)


def test_codebook_prefix_free(rng):
    codes = _random_codes(rng, 3, (4096,))
    book = huffman.build_codebook(np.bincount(codes, minlength=256))
    cws = [(int(book.codes_msb[s]), int(book.lengths[s]))
           for s in range(256) if book.lengths[s] > 0]
    for i, (c1, l1) in enumerate(cws):
        for c2, l2 in cws[i + 1:]:
            la = min(l1, l2)
            assert (c1 >> (l1 - la)) != (c2 >> (l2 - la)), "prefix violation"


def test_codebook_length_limit():
    # extreme skew would produce >16-bit codes without limiting
    hist = np.zeros(256, np.int64)
    hist[:40] = np.logspace(0, 12, 40).astype(np.int64)
    book = huffman.build_codebook(hist)
    assert book.lengths.max() <= huffman.MAX_CODE_LEN


def test_degenerate_single_symbol():
    hist = np.zeros(256, np.int64)
    hist[7] = 100
    book = huffman.build_codebook(hist)
    assert book.lengths[7] == 1
    codes = np.full((3, 8), 7, np.uint8)
    words, nbits = huffman.encode_block(codes, book)
    dec = huffman.decode_block(words, nbits, book, 8)
    assert (dec == codes).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), skew=st.floats(0.5, 20.0),
       S=st.integers(1, 8), L=st.integers(1, 24))
def test_roundtrip_numpy_oracle(seed, skew, S, L):
    rng = np.random.default_rng(seed)
    codes = _random_codes(rng, skew, (S, L))
    book = huffman.build_codebook(np.bincount(codes.reshape(-1), minlength=256))
    words, nbits = huffman.encode_block(codes, book)
    dec = huffman.decode_block(words, nbits, book, L)
    assert (dec == codes).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), skew=st.floats(1.0, 10.0))
def test_jax_encode_matches_oracle(seed, skew):
    rng = np.random.default_rng(seed)
    codes = _random_codes(rng, skew, (4, 16))
    book = huffman.build_codebook(np.bincount(codes.reshape(-1), minlength=256))
    w_np, nb_np = huffman.encode_block(codes, book)
    cl, ln = book.as_encode_tables()
    cap = codes.size * 16 // 32 + 2
    w_j, nb_j, _ = huffman.encode_block_jax(jnp.asarray(codes), cl, ln, cap)
    assert (np.asarray(nb_j) == nb_np).all()
    assert (np.asarray(w_j)[: len(w_np)] == w_np).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_jax_decode_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    codes = _random_codes(rng, 4, (6, 12))
    book = huffman.build_codebook(np.bincount(codes.reshape(-1), minlength=256))
    w, nb = huffman.encode_block(codes, book)
    ch, isym, sym = book.as_device_tables()
    dec = huffman.decode_block_jax(
        jnp.asarray(np.concatenate([w, np.zeros(2, np.uint32)])),
        jnp.asarray(nb), ch, isym, sym, 12, int(nb.max()))
    assert (np.asarray(dec) == codes).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), skew=st.floats(0.5, 30.0),
       S=st.integers(1, 10), L=st.integers(1, 24))
def test_lut_decode_matches_tree_walk(seed, skew, S, L):
    """The chunked direct-lookup decoder ≡ the bit-serial tree walk, for
    any codebook the limiter can produce (1- and 2-probe regimes both) —
    including padding streams (nbits = 0) and a truncated stream, which
    both decoders must leave as zeros."""
    rng = np.random.default_rng(seed)
    codes = _random_codes(rng, skew, (S, L))
    book = huffman.build_codebook(np.bincount(codes.reshape(-1), minlength=256))
    w, nb = huffman.encode_block(codes, book)
    # A zero-bit padding stream in the middle, and a truncated final stream
    # (budget cut below its encoded bits so its tail codewords are partial).
    nb = np.insert(nb, S // 2, 0).astype(np.uint16)
    nb[-1] = nb[-1] // 2
    pay = jnp.asarray(np.concatenate([w, np.zeros(2, np.uint32)]))
    ch, isym, sym = book.as_device_tables()
    walk = huffman.decode_block_jax(pay, jnp.asarray(nb), ch, isym, sym,
                                    L, int(nb.max()))
    lut = huffman.decode_block_lut_jax(pay, jnp.asarray(nb),
                                       jnp.asarray(book.decode_lut()),
                                       L, book.decode_probes)
    assert (np.asarray(walk)[S // 2] == 0).all()  # padding stream is zeros
    assert (np.asarray(lut) == np.asarray(walk)).all()


def test_compression_close_to_entropy(rng):
    codes = _random_codes(rng, 2, (8192,))
    hist = np.bincount(codes, minlength=256)
    book = huffman.build_codebook(hist)
    p = hist / hist.sum()
    ent = -(p[p > 0] * np.log2(p[p > 0])).sum()
    avg = book.expected_bits_per_symbol(hist)
    assert ent <= avg <= ent + 1.0  # Huffman is within 1 bit of entropy
    assert avg < 8  # beats raw u8 on skewed data


def test_tree_is_branchless_compatible():
    """children/is_symbol arrays: leaves have children 0 (reset-to-root)."""
    rng = np.random.default_rng(1)
    codes = _random_codes(rng, 3, (2048,))
    book = huffman.build_codebook(np.bincount(codes, minlength=256))
    leaves = book.is_symbol == 1
    assert (book.children[leaves] == 0).all()
