"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C
from repro.core import huffman
from repro.kernels import ops, ref
from repro.kernels.fused_kv_attn import fused_cache_attention_pallas
from repro.kernels.huffman_decode import (huffman_attn_scores_pallas,
                                          huffman_decode_pallas)
from repro.kernels.pack_encode import quant_pack_pallas


def _kernel_args(c):
    return (c.k_store, c.k_min, c.k_step, c.v_store, c.v_min, c.v_step,
            c.k_buf, c.v_buf,
            jnp.minimum(c.n_flushed, c.spec.n_blocks), c.buf_len)


@pytest.mark.parametrize("layout", ["packed", "raw"])
@pytest.mark.parametrize("B,Hkv,G,S,D,T", [
    (1, 1, 1, 32, 16, 8),
    (2, 2, 3, 96, 32, 16),
    (1, 4, 2, 64, 64, 16),    # MXU-ish head_dim
    (2, 1, 8, 48, 24, 8),     # odd head_dim
])
def test_fused_cache_attention_sweep(B, Hkv, G, S, D, T, layout, rng):
    """Kernel (buffer tail folded in) vs the vmapped tile-decode oracle,
    through both the packed unpack decoder and the raw passthrough."""
    spec = C.CacheSpec(layout=layout, block_size=T, max_seq=2 * S,
                       rel_scale_k=0.05, rel_scale_v=0.15)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    c = C.prefill(spec, k, v)
    tile = spec.impl.tile_decode(spec, D)
    kw = dict(tile=tile, block_size=T)
    out_r = ref.fused_cache_attention_ref(q, *_kernel_args(c), **kw)
    out_p = fused_cache_attention_pallas(q, *_kernel_args(c), **kw)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_decode_attention_dtypes(dtype, rng):
    spec = C.CacheSpec(layout="packed", block_size=8, max_seq=64)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 16)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(1, 4, 16))).astype(dtype)
    c = C.prefill(spec, k, v)
    o1 = ops.cache_decode_attention(c, q, impl="pallas")
    o2 = ops.cache_decode_attention(c, q, impl="xla")
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=2e-2)


def test_fused_matches_cache_attend_end_to_end(rng):
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=128)
    k = jnp.asarray(rng.normal(size=(2, 2, 72, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 72, 16)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(2, 4, 16)).astype(np.float32))
    c = C.prefill(spec, k, v)  # 4 full blocks + 8 in buffer
    assert (np.asarray(c.buf_len) == 8).all()
    out_kernel = ops.cache_decode_attention(c, q, impl="pallas")
    out_cache = C.attend(c, q)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_cache),
                               atol=5e-3)


def test_fused_empty_store_buffer_only(rng):
    """nb_valid == 0: everything comes from the raw buffer."""
    spec = C.CacheSpec(layout="packed", block_size=16, max_seq=64)
    k = jnp.asarray(rng.normal(size=(1, 2, 5, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 5, 16)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(1, 2, 16)).astype(np.float32))
    c = C.prefill(spec, k, v)
    assert (np.asarray(c.n_flushed) == 0).all()
    out = ops.cache_decode_attention(c, q, impl="pallas")
    ref_out = C.reference_attend(k, v, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=5e-3)


# ---------------------------------------------------------------------------
# Huffman kernels
# ---------------------------------------------------------------------------


def _encode_blocks(rng, NBLK, S, L, skew=3):
    codes = np.clip(np.round(rng.normal(8, skew, (NBLK, S, L))), 0, 30).astype(np.uint8)
    book = huffman.build_codebook(np.bincount(codes.reshape(-1), minlength=256))
    payloads, nbits = [], []
    for n in range(NBLK):
        w, nb = huffman.encode_block(codes[n], book)
        payloads.append(w)
        nbits.append(nb)
    W = max(len(w) for w in payloads)
    pay = np.zeros((NBLK, W), np.uint32)
    for n, w in enumerate(payloads):
        pay[n, : len(w)] = w
    return codes, book, pay, np.stack(nbits)


@pytest.mark.parametrize("NBLK,S,L", [(1, 4, 8), (3, 8, 16), (2, 16, 12)])
def test_huffman_decode_kernel_sweep(NBLK, S, L, rng):
    codes, book, pay, nbits = _encode_blocks(rng, NBLK, S, L)
    ch, isym, sym = book.as_device_tables()
    maxbits = int(nbits.sum(axis=1).max())
    dec = huffman_decode_pallas(jnp.asarray(pay), jnp.asarray(nbits),
                                ch, isym, sym, L, maxbits)
    assert (np.asarray(dec) == codes).all()


def test_huffman_fused_scores_kernel(rng):
    NBLK, S, D = 2, 8, 16
    codes, book, pay, nbits = _encode_blocks(rng, NBLK, S, D)
    ch, isym, sym = book.as_device_tables()
    maxbits = int(nbits.sum(axis=1).max())
    kmn = rng.normal(size=(NBLK, D)).astype(np.float32)
    kst = (0.05 * rng.uniform(1, 2, (NBLK, D))).astype(np.float32)
    q = rng.normal(size=(D,)).astype(np.float32)
    sc = huffman_attn_scores_pallas(
        jnp.asarray(pay), jnp.asarray(nbits), ch, isym, sym,
        jnp.asarray(kmn), jnp.asarray(kst), jnp.asarray(q), maxbits, scale=0.25)
    for n in range(NBLK):
        expect = ref.huffman_attn_scores_ref(
            jnp.asarray(pay[n]), jnp.asarray(nbits[n]), ch, isym, sym,
            jnp.asarray(kmn[n]), jnp.asarray(kst[n]), jnp.asarray(q), maxbits) * 0.25
        np.testing.assert_allclose(np.asarray(sc[n]), np.asarray(expect),
                                   atol=1e-4)


def test_decode_lut_entries_bounded(rng):
    """LUT invariants: consumed ∈ [1, 8], emitted entries reset to the root,
    probes ≤ 2 under the MAX_CODE_LEN limit.  (Deterministic — lives here
    rather than test_huffman.py so the production LUT decoder keeps tier-1
    coverage when the optional hypothesis dep gates that module away.)"""
    codes = np.clip(np.round(rng.normal(8, 6, (4096,))), 0, 255).astype(np.uint8)
    book = huffman.build_codebook(np.bincount(codes, minlength=256))
    lut = huffman.build_decode_lut(book)
    consumed = (lut >> 8) & 0xF
    emit = (lut >> 12) & 1
    nxt = lut >> 16
    assert consumed.min() >= 1 and consumed.max() <= huffman.LUT_CHUNK_BITS
    assert (nxt[emit == 1] == 0).all()
    assert (nxt < book.n_nodes).all()
    assert 1 <= book.decode_probes <= 2


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_lut_decode_matches_walk_deterministic(seed):
    """Deterministic LUT ≡ tree-walk equivalence (incl. a zero-bit padding
    stream and a truncated final stream) — the hypothesis-gated property
    test in test_huffman.py widens this sweep when the dep is present."""
    rng2 = np.random.default_rng(seed)
    skew = float(rng2.uniform(0.5, 30.0))
    S, L = int(rng2.integers(2, 10)), int(rng2.integers(2, 24))
    codes = np.clip(np.round(rng2.normal(8, skew, (S, L))), 0, 255).astype(np.uint8)
    book = huffman.build_codebook(np.bincount(codes.reshape(-1), minlength=256))
    w, nb = huffman.encode_block(codes, book)
    nb = np.insert(nb, S // 2, 0).astype(np.uint16)
    nb[-1] = nb[-1] // 2
    pay = jnp.asarray(np.concatenate([w, np.zeros(2, np.uint32)]))
    ch, isym, sym = book.as_device_tables()
    walk = huffman.decode_block_jax(pay, jnp.asarray(nb), ch, isym, sym,
                                    L, int(nb.max()))
    lut = huffman.decode_block_lut_jax(pay, jnp.asarray(nb),
                                       jnp.asarray(book.decode_lut()),
                                       L, book.decode_probes)
    assert (np.asarray(walk)[S // 2] == 0).all()
    assert (np.asarray(lut) == np.asarray(walk)).all()


# ---------------------------------------------------------------------------
# Store-stage kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("token_wise", [False, True])
@pytest.mark.parametrize("NBLK,T,D,bits", [(2, 8, 16, 5), (4, 16, 32, 3), (1, 16, 24, 8)])
def test_quant_pack_kernel_sweep(NBLK, T, D, bits, token_wise, rng):
    x = jnp.asarray(rng.normal(size=(NBLK, T, D)).astype(np.float32))
    w_p, mn_p, st_p = quant_pack_pallas(x, 0.05, bits, token_wise)
    w_r, mn_r, st_r = ref.quant_pack_ref(x, 0.05, bits, token_wise)
    assert (np.asarray(w_p) == np.asarray(w_r)).all()
    np.testing.assert_allclose(np.asarray(mn_p), np.asarray(mn_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_p), np.asarray(st_r), atol=1e-6)


def test_ops_quant_pack_wrapper(rng):
    x = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
    for impl in ("pallas", "xla"):
        w, mn, st = ops.quant_pack(x, rel_scale=0.05, bits=5, token_wise=False,
                                   impl=impl)
        assert w.dtype == jnp.uint32
