"""MoE dispatch: sort-based grouped matmul vs dense-gather reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import ModelConfig

CFG = ModelConfig(name="m", family="moe", n_layers=1, d_model=16, vocab_size=64,
                  n_heads=2, n_kv_heads=1, head_dim=8, n_experts=4, top_k=2,
                  d_ff_expert=32, capacity_factor=8.0)


def _dense_reference(params, cfg, x):
    """Compute every expert on every token, combine by router weights."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("nd,edf->enf", xf, params["w_gate"]))
    h = h * jnp.einsum("nd,edf->enf", xf, params["w_up"])
    y_all = jnp.einsum("enf,efd->end", h, params["w_down"])  # [E, N, d]
    out = jnp.zeros_like(xf)
    for k in range(cfg.top_k):
        w = top_p[:, k][:, None]
        out = out + w * jnp.take_along_axis(
            y_all, top_e[:, k][None, :, None], axis=0)[0]
    return out.reshape(B, S, d)


def test_moe_matches_dense_reference(rng):
    params, _ = moe.init_moe(jax.random.PRNGKey(0), CFG)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
    y, aux = moe.moe_apply(params, CFG, x)
    y_ref = _dense_reference(params, CFG, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-3)
    assert float(aux) >= 0


def test_capacity_drops_tokens(rng):
    import dataclasses
    cfg = dataclasses.replace(CFG, capacity_factor=0.25)
    params, _ = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, 16)).astype(np.float32))
    y_small, _ = moe.moe_apply(params, cfg, x)
    y_big, _ = moe.moe_apply(params, CFG, x)
    # dropping must change the output (some tokens lose expert contributions)
    assert float(jnp.max(jnp.abs(y_small - y_big))) > 1e-4


def test_aux_loss_favors_balance(rng):
    """A router forced to one expert must pay a higher aux loss."""
    params, _ = moe.init_moe(jax.random.PRNGKey(0), CFG)
    x = jnp.asarray(rng.normal(size=(2, 16, 16)).astype(np.float32))
    _, aux_balanced = moe.moe_apply(params, CFG, x)
    skewed = dict(params)
    skewed["router"] = params["router"] * 0 + jnp.asarray(
        np.eye(16, 4, dtype=np.float32) * 50)
    _, aux_skew = moe.moe_apply(skewed, CFG, x)
    assert float(aux_skew) > float(aux_balanced)


def test_moe_grads_flow_to_router(rng):
    params, _ = moe.init_moe(jax.random.PRNGKey(0), CFG)
    x = jnp.asarray(rng.normal(size=(1, 8, 16)).astype(np.float32))

    def loss(p):
        y, aux = moe.moe_apply(p, CFG, x)
        return jnp.sum(y**2) + aux

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_down"]))) > 0
