"""Continuous-batching scheduler: requests joining/leaving mid-flight must
be bit-identical (greedy) to solo runs, for raw and compressed layouts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models import registry
from repro.serve.scheduler import Request, Server, ServerConfig

LENS = (7, 13, 16, 24, 33)
NEWS = (3, 9, 5, 2, 7)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("yi_6b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32) for L in LENS]
    return cfg, params, prompts


def _solo_greedy(cfg, params, prompt, n_new, eos_id=None):
    """Independent oracle: B=1 block-chunked prefill — the server's unified
    admission semantics, where each chunk attends earlier blocks through
    the compressed store exactly as decode will — then step-by-step greedy
    decode, truncated at eos."""
    prompt = np.asarray(prompt, np.int32)
    T = M.cache_specs(cfg, 256)[0].block_size
    state = M.init_decode_state(cfg, 1, 256)
    lg, pos = None, 0
    while pos < len(prompt):
        C = min(T, len(prompt) - pos)
        lg, state = M.prefill_chunk(params, cfg,
                                    jnp.asarray(prompt[None, pos:pos + C]),
                                    jnp.int32(pos), state)
        pos += C
    cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    out = [int(cur[0])]
    while len(out) < n_new and (eos_id is None or out[-1] != eos_id):
        lg, state = M.decode_step(params, cfg, cur,
                                  jnp.asarray(pos, jnp.int32), state)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out.append(int(cur[0]))
        pos += 1
    return out


@pytest.mark.parametrize("layout", ["raw", "packed"])
def test_mid_flight_join_leave_matches_solo(setup, layout):
    """5 requests with mixed prompt lengths and budgets through 2 slots:
    every admission joins a batch whose other row is mid-decode, yet each
    request's greedy tokens equal its solo run."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout=layout, cache_block=16)
    server = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    server.run()
    assert server.active == 0 and server.pending == 0
    for p, n, h in zip(prompts, NEWS, handles):
        got = h.result().tokens.tolist()
        assert got == _solo_greedy(cfg, params, p, n), (layout, len(p), n)


def test_eos_truncation_and_finish_reason(setup):
    """Tokens stop at eos_id (inclusive) and the result says why."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    solo = _solo_greedy(cfg, params, prompts[1], 8)
    # pick the first token that did not occur earlier in the stream so the
    # eos cut lands exactly there
    cut = next(i for i in range(1, len(solo)) if solo[i] not in solo[:i])
    server = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    h_eos = server.submit(Request(prompt=prompts[1], max_new_tokens=8,
                                  eos_id=solo[cut]))
    h_len = server.submit(Request(prompt=prompts[2], max_new_tokens=4))
    server.run()
    r_eos, r_len = h_eos.result(), h_len.result()
    assert r_eos.tokens.tolist() == solo[: cut + 1]  # truncated, eos included
    assert r_eos.finish_reason == "eos"
    assert len(r_len.tokens) == 4 and r_len.finish_reason == "length"


def test_streaming_tokens_iterator(setup):
    """handle.tokens() yields incrementally and agrees with result()."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    h1 = server.submit(Request(prompt=prompts[0], max_new_tokens=6))
    h2 = server.submit(Request(prompt=prompts[3], max_new_tokens=3))
    streamed = list(h1.tokens())
    assert streamed == h1.result().tokens.tolist()
    assert len(streamed) == 6
    assert h2.done  # pumping h1's stream also drove h2 to completion
    assert len(h2.result().tokens) == 3


def test_queue_deeper_than_slots(setup):
    """8 heterogeneous requests through 3 slots (the acceptance workload):
    everything completes bit-identical to solo runs, slots are reused, and
    per-request timing is individual."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed")
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8 + 3 * i).astype(np.int32),
                    max_new_tokens=2 + (i % 4))
            for i in range(8)]
    server = Server(cfg, params, ServerConfig(max_slots=3, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(r) for r in reqs]
    server.run()
    results = [h.result() for h in handles]
    for r, req in zip(results, reqs):
        assert r.tokens.tolist() == _solo_greedy(cfg, params, req.prompt,
                                                 req.max_new_tokens)
        assert r.prompt_len == len(req.prompt)
        assert r.prefill_s > 0 and r.gen_s >= 0
    # timings are per-request, not group-shared
    assert len({r.gen_s for r in results}) > 1


def test_ljf_policy_reorders_but_preserves_tokens(setup):
    """Longest-job-first admission changes only scheduling, never tokens."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=256, policy="ljf"),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    server.run()
    for p, n, h in zip(prompts, NEWS, handles):
        assert h.result().tokens.tolist() == _solo_greedy(cfg, params, p, n)


def test_single_token_budget_never_occupies_slot(setup):
    """max_new_tokens=1 finishes at prefill and leaves slots free."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params, ServerConfig(max_slots=1, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    hs = [server.submit(Request(prompt=p, max_new_tokens=1)) for p in prompts[:3]]
    server.run()
    for p, h in zip(prompts, hs):
        assert h.result().tokens.tolist() == _solo_greedy(cfg, params, p, 1)
    assert server.active == 0


def test_prefill_chunk_tokens_validation(setup):
    """Satellite regression: the chunk-budget knob is validated by NAME —
    positivity at config construction (mirroring CacheSpec's
    window % block_size check), block-multiplicity against the resolved
    spec at server construction."""
    cfg, params, _ = setup
    for bad in (0, -8):
        with pytest.raises(ValueError, match="prefill_chunk_tokens"):
            ServerConfig(prefill_chunk_tokens=bad)
    with pytest.raises(ValueError, match="prefill_mode"):
        ServerConfig(prefill_mode="sometimes")
    T = M.cache_specs(cfg, 256)[0].block_size
    with pytest.raises(ValueError) as e:
        Server(cfg, params, ServerConfig(max_slots=2, max_seq=256,
                                         prefill_chunk_tokens=T + 1),
               q_chunk=32, kv_chunk=32)
    assert "prefill_chunk_tokens" in str(e.value)
    assert "block_size" in str(e.value)


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi", "huffman"])
def test_chunked_vs_solo_admission_bit_identity_dense(setup, layout):
    """Bit-identity matrix, dense leg: interleaved chunked admission (the
    default) must produce the same greedy tokens as the blocking solo
    baseline on every layout — both run the unified chunk loop, so this
    holds exactly, not approximately."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout=layout, cache_block=8)
    outs = {}
    for mode in ("chunked", "solo"):
        server = Server(cfg, params,
                        ServerConfig(max_slots=2, max_seq=256,
                                     prefill_mode=mode,
                                     prefill_chunk_tokens=8),
                        q_chunk=32, kv_chunk=32)
        hs = [server.submit(Request(prompt=p, max_new_tokens=n))
              for p, n in zip(prompts[:3], NEWS[:3])]
        server.run()
        outs[mode] = [h.result().tokens.tolist() for h in hs]
        pf = server.stats()["prefill"]
        assert pf["mode"] == mode
        assert pf["prefill_tokens"] == sum(len(p) for p in prompts[:3])
        if mode == "chunked":
            # chunked admission never freezes a live batch wholesale...
            assert pf["stalled_decode_steps"] == 0
            assert pf["chunks"] >= sum(-(-len(p) // 8) for p in prompts[:3])
            assert pf["coscheduled_tokens"] > 0
        else:
            # ...solo admission (queue deeper than slots) always does
            assert pf["stalled_decode_steps"] > 0
    assert outs["chunked"] == outs["solo"]
    for toks, n in zip(outs["chunked"], NEWS[:3]):
        assert len(toks) == n


def test_queue_wait_and_token_times_decomposition(setup):
    """Satellite: Result splits queue wait from prefill+generation and
    stamps every token — monotonic times, TTFT consistent, queued
    requests waiting longer than slot-admitted ones."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params, ServerConfig(max_slots=1, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    hs = [server.submit(Request(prompt=p, max_new_tokens=4))
          for p in prompts[:3]]
    server.run()
    rs = [h.result() for h in hs]
    for h, r in zip(hs, rs):
        assert len(r.token_times) == len(r.tokens)
        assert list(r.token_times) == sorted(r.token_times)
        assert r.ttft_s >= r.queue_wait_s >= 0
        # the decomposition anchors: TTFT is first-token stamp minus
        # submit, queue wait ends when prefill work first touches the row
        assert r.ttft_s == pytest.approx(r.token_times[0] - h._t_submit)
        assert r.queue_wait_s == pytest.approx(h._t_first - h._t_submit)
    # one slot: the 3rd request queues behind two full generations
    assert rs[2].queue_wait_s > rs[0].queue_wait_s
