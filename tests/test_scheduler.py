"""Continuous-batching scheduler: requests joining/leaving mid-flight must
be bit-identical (greedy) to solo runs, for raw and compressed layouts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models import registry
from repro.serve.scheduler import Request, Server, ServerConfig

LENS = (7, 13, 16, 24, 33)
NEWS = (3, 9, 5, 2, 7)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("yi_6b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32) for L in LENS]
    return cfg, params, prompts


def _solo_greedy(cfg, params, prompt, n_new, eos_id=None):
    """Independent oracle: B=1 prefill at the exact prompt length, then
    step-by-step greedy decode, truncated at eos."""
    lg, state = M.prefill(params, cfg, {"tokens": jnp.asarray(prompt)[None, :]},
                          256, q_chunk=32, kv_chunk=32)
    cur = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
    out = [int(cur[0])]
    pos = len(prompt)
    while len(out) < n_new and (eos_id is None or out[-1] != eos_id):
        lg, state = M.decode_step(params, cfg, cur,
                                  jnp.asarray(pos, jnp.int32), state)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out.append(int(cur[0]))
        pos += 1
    return out


@pytest.mark.parametrize("layout", ["raw", "packed"])
def test_mid_flight_join_leave_matches_solo(setup, layout):
    """5 requests with mixed prompt lengths and budgets through 2 slots:
    every admission joins a batch whose other row is mid-decode, yet each
    request's greedy tokens equal its solo run."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout=layout, cache_block=16)
    server = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    server.run()
    assert server.active == 0 and server.pending == 0
    for p, n, h in zip(prompts, NEWS, handles):
        got = h.result().tokens.tolist()
        assert got == _solo_greedy(cfg, params, p, n), (layout, len(p), n)


def test_eos_truncation_and_finish_reason(setup):
    """Tokens stop at eos_id (inclusive) and the result says why."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    solo = _solo_greedy(cfg, params, prompts[1], 8)
    # pick the first token that did not occur earlier in the stream so the
    # eos cut lands exactly there
    cut = next(i for i in range(1, len(solo)) if solo[i] not in solo[:i])
    server = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    h_eos = server.submit(Request(prompt=prompts[1], max_new_tokens=8,
                                  eos_id=solo[cut]))
    h_len = server.submit(Request(prompt=prompts[2], max_new_tokens=4))
    server.run()
    r_eos, r_len = h_eos.result(), h_len.result()
    assert r_eos.tokens.tolist() == solo[: cut + 1]  # truncated, eos included
    assert r_eos.finish_reason == "eos"
    assert len(r_len.tokens) == 4 and r_len.finish_reason == "length"


def test_streaming_tokens_iterator(setup):
    """handle.tokens() yields incrementally and agrees with result()."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params, ServerConfig(max_slots=2, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    h1 = server.submit(Request(prompt=prompts[0], max_new_tokens=6))
    h2 = server.submit(Request(prompt=prompts[3], max_new_tokens=3))
    streamed = list(h1.tokens())
    assert streamed == h1.result().tokens.tolist()
    assert len(streamed) == 6
    assert h2.done  # pumping h1's stream also drove h2 to completion
    assert len(h2.result().tokens) == 3


def test_queue_deeper_than_slots(setup):
    """8 heterogeneous requests through 3 slots (the acceptance workload):
    everything completes bit-identical to solo runs, slots are reused, and
    per-request timing is individual."""
    cfg, params, _ = setup
    cfg = dataclasses.replace(cfg, cache_layout="packed")
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8 + 3 * i).astype(np.int32),
                    max_new_tokens=2 + (i % 4))
            for i in range(8)]
    server = Server(cfg, params, ServerConfig(max_slots=3, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(r) for r in reqs]
    server.run()
    results = [h.result() for h in handles]
    for r, req in zip(results, reqs):
        assert r.tokens.tolist() == _solo_greedy(cfg, params, req.prompt,
                                                 req.max_new_tokens)
        assert r.prompt_len == len(req.prompt)
        assert r.prefill_s > 0 and r.gen_s >= 0
    # timings are per-request, not group-shared
    assert len({r.gen_s for r in results}) > 1


def test_ljf_policy_reorders_but_preserves_tokens(setup):
    """Longest-job-first admission changes only scheduling, never tokens."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params,
                    ServerConfig(max_slots=2, max_seq=256, policy="ljf"),
                    q_chunk=32, kv_chunk=32)
    handles = [server.submit(Request(prompt=p, max_new_tokens=n))
               for p, n in zip(prompts, NEWS)]
    server.run()
    for p, n, h in zip(prompts, NEWS, handles):
        assert h.result().tokens.tolist() == _solo_greedy(cfg, params, p, n)


def test_single_token_budget_never_occupies_slot(setup):
    """max_new_tokens=1 finishes at prefill and leaves slots free."""
    cfg, params, prompts = setup
    cfg = dataclasses.replace(cfg, cache_layout="raw")
    server = Server(cfg, params, ServerConfig(max_slots=1, max_seq=256),
                    q_chunk=32, kv_chunk=32)
    hs = [server.submit(Request(prompt=p, max_new_tokens=1)) for p in prompts[:3]]
    server.run()
    for p, h in zip(prompts, hs):
        assert h.result().tokens.tolist() == _solo_greedy(cfg, params, p, 1)
    assert server.active == 0
