"""CompressedKVCache: growing-cache invariants, layout accuracy ordering,
append==prefill consistency, SWA block-aligned eviction (paper §3.2)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need the optional dev dep; the rest runs without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import cache as C


def _mk(rng, B=2, Hkv=2, S=96, D=16):
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, Hkv * 2, D)).astype(np.float32))
    return k, v, q


SPEC = C.CacheSpec(layout="packed", block_size=16, max_seq=256,
                   rel_scale_k=0.02, rel_scale_v=0.05)


def test_prefill_attend_close_to_exact(rng):
    k, v, q = _mk(rng)
    c = C.prefill(SPEC, k, v)
    out = C.attend(c, q)
    ref = C.reference_attend(k, v, q)
    assert float(jnp.max(jnp.abs(out - ref))) < 0.05


def test_raw_layout_is_bf16_exact(rng):
    k, v, q = _mk(rng)
    spec = dataclasses.replace(SPEC, layout="raw")
    c = C.prefill(spec, k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
    out = C.attend(c, q)
    ref = C.reference_attend(k, v, q)
    assert float(jnp.max(jnp.abs(out - ref))) < 0.01


def test_layout_accuracy_ordering(rng):
    """KVComp-packed at the paper's scales beats KIVI-2bit (Fig. 7 claim)."""
    k, v, q = _mk(rng, S=128)
    ref = C.reference_attend(k, v, q)

    def err(spec):
        return float(jnp.max(jnp.abs(C.attend(C.prefill(spec, k, v), q) - ref)))

    e_kvcomp = err(dataclasses.replace(SPEC, rel_scale_k=0.05, rel_scale_v=0.15))
    e_kivi2 = err(dataclasses.replace(SPEC, layout="kivi", kivi_bits=2))
    assert e_kvcomp < e_kivi2


def test_append_matches_prefill(rng):
    k, v, q = _mk(rng, S=80)
    k2 = jnp.asarray(rng.normal(size=(2, 2, 33, 16)).astype(np.float32))
    v2 = jnp.asarray(rng.normal(size=(2, 2, 33, 16)).astype(np.float32))
    c = C.prefill(SPEC, k, v)
    app = jax.jit(C.append)
    for t in range(33):
        c = app(c, k2[:, :, t], v2[:, :, t])
    c2 = C.prefill(SPEC, jnp.concatenate([k, k2], 2), jnp.concatenate([v, v2], 2))
    assert (np.asarray(c.n_flushed) == np.asarray(c2.n_flushed)).all()
    assert (np.asarray(c.buf_len) == np.asarray(c2.buf_len)).all()
    o1, o2 = C.attend(c, q), C.attend(c2, q)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 0.02  # bf16 buffer requantization


def test_per_row_positions_independent(rng):
    """Rows of one cache advance at independent positions (the continuous-
    batching contract): each row appends at its own buf_len, flushes its own
    blocks at different steps, and stays bit-identical to a solo B=1 cache
    following the same trajectory."""
    k, v, q = _mk(rng, S=48)
    c40 = C.prefill(SPEC, k[:, :, :40], v[:, :, :40])   # row0: 2 blocks + 8 buf
    c48 = C.prefill(SPEC, k, v)                          # row1: 3 blocks + 0 buf
    mixed = jax.tree.map(lambda a, b: jnp.stack([a[0], b[1]]), c40, c48)
    solo0 = jax.tree.map(lambda x: x[:1], c40)
    solo1 = jax.tree.map(lambda x: x[1:], c48)
    app = jax.jit(C.append)
    for _ in range(20):
        kn = jnp.asarray(rng.normal(size=(2, 2, 16)).astype(np.float32))
        vn = jnp.asarray(rng.normal(size=(2, 2, 16)).astype(np.float32))
        mixed = app(mixed, kn, vn)
        solo0 = app(solo0, kn[:1], vn[:1])
        solo1 = app(solo1, kn[1:], vn[1:])
    assert np.asarray(mixed.total_len).tolist() == [60, 68]
    out = C.attend(mixed, q)
    np.testing.assert_array_equal(np.asarray(out[:1]), np.asarray(C.attend(solo0, q[:1])))
    np.testing.assert_array_equal(np.asarray(out[1:]), np.asarray(C.attend(solo1, q[1:])))


if HAVE_HYPOTHESIS:
    _growing_deco = lambda f: settings(max_examples=10, deadline=None)(
        given(seed=st.integers(0, 2**31 - 1), n_append=st.integers(0, 40))(f))
else:
    _growing_deco = pytest.mark.skip(reason="hypothesis not installed")


@_growing_deco
def test_growing_invariants(seed, n_append):
    """total_len tracks appends; flush count is floor(total/block)."""
    rng = np.random.default_rng(seed)
    k, v, _ = _mk(rng, S=32)
    c = C.prefill(SPEC, k, v)
    app = jax.jit(C.append)
    for t in range(n_append):
        kn = jnp.asarray(rng.normal(size=(2, 2, 16)).astype(np.float32))
        vn = jnp.asarray(rng.normal(size=(2, 2, 16)).astype(np.float32))
        c = app(c, kn, vn)
    total = 32 + n_append
    assert (np.asarray(c.total_len) == total).all()  # per-row vectors
    assert (np.asarray(c.n_flushed) == total // SPEC.block_size).all()
    assert (np.asarray(c.buf_len) == total % SPEC.block_size).all()


def test_swa_ring_eviction(rng):
    k, v, q = _mk(rng, S=96)
    spec = dataclasses.replace(SPEC, window=32, max_seq=512)
    c = C.prefill(spec, k, v)
    assert spec.n_blocks == 2
    assert (np.asarray(c.total_len) == 32).all()  # window-capped
    out = C.attend(c, q)
    ref = C.reference_attend(k, v, q, window=32)
    assert float(jnp.max(jnp.abs(out - ref))) < 0.05


def test_swa_ring_append_wraps(rng):
    k, v, q = _mk(rng, S=32)
    spec = dataclasses.replace(SPEC, window=32, max_seq=512)
    c = C.prefill(spec, k, v)
    app = jax.jit(C.append)
    extra_k = rng.normal(size=(48, 2, 2, 16)).astype(np.float32)
    extra_v = rng.normal(size=(48, 2, 2, 16)).astype(np.float32)
    for t in range(48):
        c = app(c, jnp.asarray(extra_k[t]), jnp.asarray(extra_v[t]))
    # ring holds the last 32 tokens (block-aligned window)
    assert (np.asarray(c.total_len) == 32).all()
    k_all = jnp.concatenate([k, jnp.asarray(extra_k).transpose(1, 2, 0, 3)], 2)
    v_all = jnp.concatenate([v, jnp.asarray(extra_v).transpose(1, 2, 0, 3)], 2)
    out = C.attend(c, q)
    ref = C.reference_attend(k_all, v_all, q, window=32)
    assert float(jnp.max(jnp.abs(out - ref))) < 0.05


def test_memory_footprint_ordering(rng):
    """packed < raw bytes at rest — the paper's memory-reduction claim."""
    k, v, _ = _mk(rng, S=128)

    def nbytes(spec):
        c = C.prefill(spec, k, v)
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))

    raw = nbytes(dataclasses.replace(SPEC, layout="raw"))
    packed = nbytes(dataclasses.replace(SPEC, rel_scale_k=0.05, rel_scale_v=0.15))
    kivi = nbytes(dataclasses.replace(SPEC, layout="kivi", kivi_bits=2))
    assert packed < raw
    assert kivi < raw
