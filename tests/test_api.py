"""Public facade + cache-layout registry: round-trip parity of every
registered layout, huffman end-to-end decode agreement, per-layer
CompressionPolicy overrides, unknown-layout error paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import cache as C
from repro.core import layouts
from repro.core.policy import CompressionPolicy, LayerOverride, TensorPolicy


def _kvq(rng, B=2, Hkv=2, S=96, D=16):
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, Hkv * 2, D)).astype(np.float32))
    return k, v, q


def _policy(layout):
    return CompressionPolicy(layout=layout, block_size=16,
                             k=TensorPolicy(rel_scale=0.02),
                             v=TensorPolicy(rel_scale=0.05))


def test_available_layouts_has_builtins():
    names = api.available_layouts()
    assert {"raw", "packed", "kivi", "huffman"} <= set(names)


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi", "huffman"])
def test_roundtrip_parity_all_layouts(layout, rng):
    """compress -> decompress reconstructs within each layout's error bound;
    attend through the facade tracks exact attention."""
    k, v, q = _kvq(rng)
    cache = api.compress(k, v, policy=_policy(layout), max_seq=256)
    kd, vd = api.decompress(cache)
    assert kd.shape == k.shape and vd.shape == v.shape
    k_err = float(jnp.max(jnp.abs(kd.astype(jnp.float32) - k)))
    v_err = float(jnp.max(jnp.abs(vd.astype(jnp.float32) - v)))
    if layout == "raw":
        assert k_err < 0.02 and v_err < 0.02  # bf16 rounding only
    elif layout == "kivi":
        assert k_err < 2.0 and v_err < 2.0  # 2-bit baseline: coarse
    else:
        # error-bounded quantizer: |x - x̂| <= step/2, step = rel·(max−min)
        assert k_err < 0.1 and v_err < 0.2
    out = api.attend(cache, q)
    ref = C.reference_attend(k, v, q)
    tol = 0.3 if layout == "kivi" else 0.05
    assert float(jnp.max(jnp.abs(out - ref))) < tol


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi", "huffman"])
def test_make_cache_serves_all_layouts(layout, rng):
    """An empty api.make_cache must accept appends and serve attention."""
    B, Hkv, D = 2, 2, 16
    cache = api.make_cache(B, Hkv, D, policy=_policy(layout), max_seq=64)
    rows = 20  # > block_size: exercises a compressed flush too
    ks = jnp.asarray(rng.normal(size=(rows, B, Hkv, D)).astype(np.float32))
    vs = jnp.asarray(rng.normal(size=(rows, B, Hkv, D)).astype(np.float32))
    for t in range(rows):
        cache = api.append(cache, ks[t], vs[t])
    assert (np.asarray(cache.total_len) == rows).all()
    q = jnp.asarray(rng.normal(size=(B, Hkv * 2, D)).astype(np.float32))
    out = api.attend(cache, q)
    ref = C.reference_attend(ks.transpose(1, 2, 0, 3), vs.transpose(1, 2, 0, 3), q)
    tol = 0.5 if layout == "kivi" else 0.05  # 2-bit over a 16-token block
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_huffman_end_to_end_decode_agreement(rng):
    """Huffman is entropy coding on top of the packed quantizer: decoded
    blocks must agree BIT-FOR-BIT with the packed layout's, through both the
    prefill and the append/flush paths, and attention must match."""
    k, v, q = _kvq(rng)
    cp = api.compress(k, v, policy=_policy("packed"), max_seq=256)
    ch = api.compress(k, v, policy=_policy("huffman"), max_seq=256)
    kp, vp = cp.spec.impl.fetch(cp.spec, cp)
    kh, vh = ch.spec.impl.fetch(ch.spec, ch)
    assert bool(jnp.all(kp == kh)) and bool(jnp.all(vp == vh))
    # Same backend for both layouts: bit-identical codes+scales through the
    # identical blockwise math must give bit-identical attention (pinning
    # "xla" keeps this invariant under the CI REPRO_ATTN_BACKEND matrix —
    # the fused tile decoders differ per layout, so cross-LAYOUT
    # bit-identity is only guaranteed on the shared blockwise path).
    np.testing.assert_array_equal(np.asarray(api.attend(cp, q, backend="xla")),
                                  np.asarray(api.attend(ch, q, backend="xla")))
    # append until both flush one more block; agreement must survive
    for t in range(16):
        kn = jnp.asarray(rng.normal(size=k.shape[:2] + k.shape[-1:]).astype(np.float32))
        vn = jnp.asarray(rng.normal(size=k.shape[:2] + k.shape[-1:]).astype(np.float32))
        cp = api.append(cp, kn, vn)
        ch = api.append(ch, kn, vn)
    assert (np.asarray(cp.n_flushed) == 7).all() and (np.asarray(ch.n_flushed) == 7).all()
    kp, vp = cp.spec.impl.fetch(cp.spec, cp)
    kh, vh = ch.spec.impl.fetch(ch.spec, ch)
    assert bool(jnp.all(kp == kh)) and bool(jnp.all(vp == vh))


def test_huffman_cache_decode_jits(rng):
    """The servable huffman path must trace under jit (static capacities)."""
    k, v, q = _kvq(rng, S=32)
    spec = api.make_spec(_policy("huffman"), max_seq=64)

    @jax.jit
    def roundtrip(k, v, q):
        cache = C.prefill(spec, k, v)
        return C.attend(cache, q)

    out = roundtrip(k, v, q)
    assert bool(jnp.isfinite(out).all())


def test_unknown_layout_name_errors(rng):
    with pytest.raises(ValueError, match="unknown cache layout"):
        layouts.get_layout("nope")
    with pytest.raises(ValueError, match="unknown cache layout"):
        CompressionPolicy(layout="nope")
    with pytest.raises(ValueError, match="unknown cache layout"):
        api.make_cache(1, 1, 8, policy=dataclasses.replace(
            CompressionPolicy(), overrides=(LayerOverride(layers=(0,), layout="nope"),)))


def test_register_layout_extends_registry():
    @api.register_layout("test-alias-raw")
    class AliasRaw(layouts.RawLayout):
        pass

    try:
        assert "test-alias-raw" in api.available_layouts()
        cache = api.make_cache(1, 1, 8, policy=CompressionPolicy(
            layout="test-alias-raw", block_size=8), max_seq=32)
        assert cache.spec.impl.name == "test-alias-raw"
    finally:
        layouts._REGISTRY.pop("test-alias-raw", None)


def test_policy_resolves_per_layer_and_per_tensor():
    pol = CompressionPolicy(
        layout="packed", block_size=16,
        k=TensorPolicy(rel_scale=0.05), v=TensorPolicy(rel_scale=0.15),
        overrides=(
            LayerOverride(layers=(1, 3), k=TensorPolicy(rel_scale=0.02)),
            LayerOverride(layers=(3,), layout="kivi", v=TensorPolicy(bits=4)),
        ))
    specs = pol.layer_specs(4, max_seq=128)
    assert [s.layout for s in specs] == ["packed", "packed", "packed", "kivi"]
    assert specs[0].rel_scale_k == 0.05 and specs[1].rel_scale_k == 0.02
    assert specs[3].rel_scale_k == 0.02          # both overrides compose
    assert specs[3].bits_v == 4                  # explicit bits override
    assert specs[0].bits_v == specs[1].bits_v    # untouched elsewhere
    assert pol.uniform is False
    assert CompressionPolicy().uniform is True


def test_per_layer_overrides_reach_model_state(rng):
    """A dense model under a non-uniform policy holds per-layer caches with
    the right specs, and prefill+decode still work end-to-end."""
    from repro.models import model as M
    from repro.models import registry

    cfg = dataclasses.replace(
        registry.get_smoke_config("yi_6b"),
        rel_scale_k=0.05,
        cache_overrides=(
            LayerOverride(layers=(1,), k=TensorPolicy(rel_scale=0.02)),
        ))
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
    lg, state = M.prefill(params, cfg, batch, max_seq=64, q_chunk=8, kv_chunk=8)
    caches = state["kv"]
    assert isinstance(caches, tuple) and len(caches) == cfg.n_layers
    assert caches[0].spec.rel_scale_k == 0.05
    assert caches[1].spec.rel_scale_k == 0.02
    assert caches[0].spec.bits_k != caches[1].spec.bits_k  # shapes differ too
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)))
    lg2, state2 = M.decode_step(params, cfg, nxt, jnp.asarray(16, jnp.int32), state)
    assert bool(jnp.isfinite(lg2).all())
    assert state2["kv"][1].spec.rel_scale_k == 0.02
    # fresh decode state mirrors the same per-layer structure
    st0 = M.init_decode_state(cfg, 2, 64)
    assert isinstance(st0["kv"], tuple)
    assert st0["kv"][1].spec.bits_k == caches[1].spec.bits_k


def test_estimate_ratio_orders_layouts(rng):
    # head_dim must be realistic: per-stream u16 metadata amortizes over D
    toks, H, D = 2048, 2, 64
    k = jnp.asarray(rng.normal(size=(toks, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(toks, H, D)).astype(np.float32))
    pol = lambda layout: CompressionPolicy(layout=layout, block_size=64)
    r_raw = api.estimate_ratio(k, v, policy=pol("raw"))
    r_packed = api.estimate_ratio(k, v, policy=pol("packed"))
    r_huff = api.estimate_ratio(k, v, policy=pol("huffman"))
    assert r_raw["ratio"] == pytest.approx(1.0)
    assert r_packed["ratio"] > 1.0
    # entropy coding beats fixed-length packing on the same codes
    assert r_huff["ratio"] > r_packed["ratio"]
