"""Dry-run record builder smoke test.

The real driver compiles every (arch × shape) cell on the production meshes
— far too heavy for tier-1 — so this runs the same ``run_cell`` record
builder end to end for one tiny decode arch on the host mesh.  It pins the
regression where ``compiled.cost_analysis()`` returns a one-dict-per-device
LIST for donated-argument decode executables (the ``--arch yi_6b``
``decode_32k`` crash: ``'list' object has no attribute 'get'``).
"""

import json

import jax
import pytest

from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig

TINY = ModelConfig(name="tiny-dryrun", family="dense", n_layers=2, d_model=32,
                   vocab_size=64, n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                   cache_block=8)


def test_dryrun_decode_record_builder_smoke(tmp_path, monkeypatch):
    jax.devices()  # init the backend BEFORE dryrun's import-time XLA_FLAGS set
    from repro.launch import dryrun
    from repro.models import registry

    monkeypatch.setattr(dryrun, "ARTIFACTS", tmp_path)
    monkeypatch.setattr(dryrun, "make_production_mesh",
                        lambda *, multi_pod=False: make_host_mesh())
    monkeypatch.setattr(registry, "get_config", lambda name: TINY)
    monkeypatch.setitem(dryrun.SHAPES, "decode_32k",
                        dict(kind="decode", seq=64, batch=2))

    rec = dryrun.run_cell("tiny-dryrun", "decode_32k", "pod",
                          analysis=False, force=True)
    assert rec["status"] == "ok", rec.get("error")
    # cost_raw is where list-returning cost_analysis() used to crash
    assert rec["cost_raw"]["flops"] >= 0.0
    assert rec["memory"]["argument_bytes"] > 0
    on_disk = json.loads(
        (tmp_path / "pod" / "tiny-dryrun__decode_32k.json").read_text())
    assert on_disk["status"] == "ok"


def test_cost_numbers_normalizes_list_and_dict():
    from repro.launch import dryrun

    class _C:
        def __init__(self, ca):
            self._ca = ca

        def cost_analysis(self):
            return self._ca

    d = {"flops": 3.0, "bytes accessed": 7.0}
    assert dryrun.cost_numbers(_C(d)) == {"flops": 3.0, "bytes": 7.0}
    assert dryrun.cost_numbers(_C([d])) == {"flops": 3.0, "bytes": 7.0}
    assert dryrun.cost_numbers(_C([])) == {"flops": 0.0, "bytes": 0.0}
