"""Trainer fault-tolerance machinery: straggler monitor, preemption, loss
decrease on a learnable task, AdamW/schedule correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, grad_compress
from repro.train.trainer import StragglerMonitor


def test_straggler_monitor_flags_slow_steps():
    times = iter([0.0, 1.0,    # step 0: 1s
                  1.0, 2.0,    # step 1: 1s
                  2.0, 7.0,    # step 2: 5s  <- straggler
                  7.0, 8.0])   # step 3: 1s
    mon = StragglerMonitor(factor=2.0, alpha=0.5, clock=lambda: next(times))
    flags = []
    for s in range(4):
        mon.start()
        flags.append(mon.stop(s))
    assert flags == [False, False, True, False]
    assert len(mon.events) == 1 and mon.events[0][0] == 2


def test_preemption_checkpoint(tmp_path):
    from repro.checkpoint import store
    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.train import step as step_lib
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.get_smoke_config("mamba2_1_3b")
    data = SyntheticCorpus(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)
    trainer = Trainer(cfg, make_host_mesh(),
                      step_lib.TrainStepConfig(remat=False, q_chunk=16, kv_chunk=16),
                      TrainerConfig(total_steps=100, ckpt_every=0,
                                    ckpt_dir=str(tmp_path), log_every=0),
                      data)
    trainer.init_state()
    trainer.request_preempt()  # preempt before the loop starts
    out = trainer.run()
    assert out["preempted"]
    assert store.latest_step(tmp_path) is not None  # final ckpt written


def test_loss_decreases_on_learnable_task(tmp_path):
    """A tiny dense model must overfit a constant-token stream."""
    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.train import step as step_lib
    from repro.train.trainer import Trainer, TrainerConfig

    class ConstData(SyntheticCorpus):
        def batch_at(self, step):
            tok = np.full((self.global_batch, self.seq_len), 7, np.int32)
            return {"tokens": tok, "labels": tok}

    cfg = registry.get_smoke_config("qwen3_1_7b")
    data = ConstData(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)
    trainer = Trainer(cfg, make_host_mesh(),
                      step_lib.TrainStepConfig(
                          remat=False, q_chunk=16, kv_chunk=16,
                          opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                                total_steps=30)),
                      TrainerConfig(total_steps=30, ckpt_every=0, log_every=0,
                                    ckpt_dir=str(tmp_path)),
                      data)
    out = trainer.run()
    first = trainer.metrics_log[0]["loss"]
    last = trainer.metrics_log[-1]["loss"]
    assert last < first * 0.5, (first, last)


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # linear warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert 0.1 < lrs[3] < 1.0                # cosine decay
    assert abs(lrs[4] - 0.1) < 1e-6          # floor


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = adamw.clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 3.0 * np.sqrt(10)) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


def test_grad_compression_error_feedback_converges():
    """Error feedback: the accumulated compressed sum tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    err = jnp.zeros((64,))
    acc_c = np.zeros(64)
    acc_t = np.zeros(64)
    for _ in range(50):
        gq, err = grad_compress.compress_decompress(g_true, err)
        acc_c += np.asarray(gq)
        acc_t += np.asarray(g_true)
    # relative error of the running sum shrinks to ~1/steps
    rel = np.abs(acc_c - acc_t).max() / np.abs(acc_t).max()
    assert rel < 0.02, rel


def test_int8_quant_roundtrip_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32) * 5)
    q, s = grad_compress.quantize_int8(x)
    deq = grad_compress.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) / 2 + 1e-6
