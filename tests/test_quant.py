"""Error-bounded quantizer contract (paper §3.1.1)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.core import quant


def _check_bound(x, q):
    """|x - x̂| <= step/2 + eps whenever the code is not clipped."""
    dq = np.asarray(q.dequantize())
    step = np.broadcast_to(np.asarray(q.step), dq.shape)
    err = np.abs(x.reshape(dq.shape) - dq)
    clipped = np.asarray(q.codes) == 255
    ok = (err <= step / 2 + 1e-5) | clipped
    assert ok.all(), f"max viol {np.max(err - step / 2)}"


@settings(max_examples=30, deadline=None)
@given(
    rel=st.floats(0.02, 0.5),
    ctx=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_k_block_error_bound(rel, ctx, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=rng.uniform(0.1, 10), size=(ctx * 16, 2, 8)).astype(np.float32)
    q = quant.quantize_k_block(jnp.asarray(x), rel, 16)
    _check_bound(x, q)


@settings(max_examples=30, deadline=None)
@given(rel=st.floats(0.02, 0.5), seed=st.integers(0, 2**31 - 1))
def test_v_token_error_bound(rel, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(32, 2, 8)).astype(np.float32)
    q = quant.quantize_v_token(jnp.asarray(x), rel)
    _check_bound(x, q)


def test_channel_quant_bound(rng):
    x = rng.normal(size=(64, 4, 16)).astype(np.float32)
    q = quant.quantize_k_channel(jnp.asarray(x), 0.1)
    _check_bound(x, q)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_kivi_levels(bits, rng):
    x = rng.normal(size=(64, 2, 8)).astype(np.float32)
    qk = quant.kivi_quantize_k(jnp.asarray(x), bits, 32)
    assert int(np.asarray(qk.codes).max()) <= 2**bits - 1
    # full range representable: max error <= step/2
    dq = np.asarray(qk.dequantize())
    step = np.broadcast_to(np.asarray(qk.step), dq.shape)
    assert (np.abs(x.reshape(dq.shape) - dq) <= step / 2 + 1e-5).all()


def test_constant_block_exact(rng):
    """Zero-range units reconstruct exactly (safe-step guard)."""
    x = np.full((32, 2, 8), 3.25, np.float32)
    q = quant.quantize_k_block(jnp.asarray(x), 0.05, 16)
    assert np.allclose(np.asarray(q.dequantize()), 3.25)


def test_stats_entropy_reasonable(rng):
    x = rng.normal(size=(128, 4, 16)).astype(np.float32)
    q = quant.quantize_k_block(jnp.asarray(x), 0.05, 32)
    s = quant.QuantStats.measure(
        jnp.asarray(x.reshape(4, 32, 4, 16)), q)
    assert 0 < s.code_entropy_bits <= 8
    assert s.clip_fraction <= 0.01


def test_smaller_scale_more_entropy(rng):
    x = rng.normal(size=(128, 2, 16)).astype(np.float32)
    ents = []
    for rel in (0.2, 0.05, 0.02):
        q = quant.quantize_k_block(jnp.asarray(x), rel, 32)
        s = quant.QuantStats.measure(jnp.asarray(x.reshape(4, 32, 2, 16)), q)
        ents.append(s.code_entropy_bits)
    assert ents[0] < ents[1] < ents[2]


def test_config_validation():
    with pytest.raises(ValueError):
        quant.QuantConfig(block_size=0)
    with pytest.raises(ValueError):
        quant.QuantConfig(rel_scale_k=0.0)
    with pytest.raises(ValueError):
        quant.QuantConfig(kivi_bits=5)
