"""End-to-end behaviour: train a tiny LM on real text, serve it with the
compressed cache, and verify the paper's claim chain on live data —
compression saves memory at (near-)zero accuracy cost."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TextCorpus
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import registry
from repro.optim import adamw
from repro.serve.engine import Engine, EngineConfig, Request, cache_memory_report
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    """Train a tiny byte-level LM for 40 steps on real on-disk text."""
    cfg = dataclasses.replace(
        registry.get_smoke_config("llama2_7b"),
        vocab_size=256, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, cache_block=16)
    data = TextCorpus(seq_len=64, global_batch=8, max_bytes=1 << 20)
    scfg = step_lib.TrainStepConfig(
        remat=False, q_chunk=64, kv_chunk=64,
        opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40))
    trainer = Trainer(cfg, make_host_mesh(), scfg,
                      TrainerConfig(total_steps=40, ckpt_every=0, log_every=0,
                                    ckpt_dir=str(tmp_path_factory.mktemp("sys_ck"))),
                      data)
    out = trainer.run()
    params = trainer.state[0]
    return cfg, params, data, out


def test_training_learns(trained):
    cfg, params, data, out = trained
    losses = [m["loss"] for m in []] or None
    # byte-level english text: random = ln(256) ≈ 5.55; must be well below
    assert out["last_loss"] < 4.0


def test_compressed_serving_agreement(trained):
    """Greedy continuations with the packed cache match the raw cache for
    most tokens (the paper's 'little/no accuracy degradation')."""
    cfg, params, data, _ = trained
    prompt = data.batch_at(123)["tokens"][0][:48].astype(np.int32)
    outs = {}
    for layout in ("raw", "packed"):
        c = dataclasses.replace(cfg, cache_layout=layout)
        eng = Engine(c, params, EngineConfig(bucket=48, max_batch=1, max_seq=128),
                     q_chunk=48, kv_chunk=48)
        outs[layout] = eng.generate(
            [Request(prompt=prompt, max_new_tokens=16)])[0].tokens
    agree = (outs["raw"] == outs["packed"]).mean()
    assert agree >= 0.75, (agree, outs)


def test_compressed_cache_saves_memory_live(trained):
    cfg, params, data, _ = trained
    toks = jnp.asarray(data.batch_at(5)["tokens"][:2, :64])
    sizes = {}
    for layout in ("raw", "packed"):
        c = dataclasses.replace(cfg, cache_layout=layout)
        _, state = M.prefill(params, c, {"tokens": toks}, 128,
                             q_chunk=32, kv_chunk=32)
        sizes[layout] = cache_memory_report(c, state)["kv_bytes"]
    assert sizes["packed"] < 0.6 * sizes["raw"], sizes


def test_perplexity_penalty_small(trained):
    """CE with compressed-cache decode ≈ CE with raw cache (< 2% relative)."""
    cfg, params, data, _ = trained
    batch = data.batch_at(7)
    toks = batch["tokens"][:4, :64]
    ces = {}
    for layout in ("raw", "packed"):
        c = dataclasses.replace(cfg, cache_layout=layout)
        _, state = M.prefill(params, c, {"tokens": jnp.asarray(toks[:, :32])}, 128,
                             q_chunk=32, kv_chunk=32)
        lp = []
        pos = 32
        cur = jnp.asarray(toks[:, 32])
        for t in range(32, 63):
            lg, state = M.decode_step(params, c, cur, jnp.asarray(pos, jnp.int32), state)
            logp = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
            nxt = jnp.asarray(toks[:, t + 1])
            lp.append(float(jnp.take_along_axis(logp, nxt[:, None], 1).mean()))
            cur = nxt
            pos += 1
        ces[layout] = -np.mean(lp)
    rel = abs(ces["packed"] - ces["raw"]) / ces["raw"]
    assert rel < 0.02, ces
