"""Bit-packing roundtrips: straddle, no-straddle, adaptive (DESIGN.md §2)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.core import bitpack


@pytest.mark.parametrize("bits", range(1, 9))
def test_straddle_roundtrip(bits, rng):
    c = rng.integers(0, 1 << bits, size=(5, 77)).astype(np.uint8)
    w = bitpack.pack_bits(jnp.asarray(c), bits)
    assert (np.asarray(w) == bitpack.pack_bits_np(c, bits)).all()
    assert (np.asarray(bitpack.unpack_bits(w, bits, 77)) == c).all()


@pytest.mark.parametrize("bits", range(1, 17))
def test_nostraddle_roundtrip(bits, rng):
    hi = 1 << min(bits, 8)
    c = rng.integers(0, hi, size=(3, 130)).astype(np.uint8)
    w = bitpack.pack_nostraddle(jnp.asarray(c), bits)
    u = bitpack.unpack_nostraddle(w, bits, 130)
    assert (np.asarray(u) == c).all()
    # no-straddle wastes at most (32 mod bits) bits per word
    assert w.shape[-1] == bitpack.nostraddle_words(130, bits)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(1, 8),
       n=st.integers(1, 200))
def test_nostraddle_property(seed, bits, n):
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 1 << bits, size=(2, n)).astype(np.uint8)
    w = bitpack.pack_nostraddle(jnp.asarray(c), bits)
    assert (np.asarray(bitpack.unpack_nostraddle(w, bits, n)) == c).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), maxval=st.integers(1, 255))
def test_adaptive_roundtrip(seed, maxval):
    rng = np.random.default_rng(seed)
    c = rng.integers(0, maxval + 1, size=(8, 64)).astype(np.uint8)
    ap = bitpack.pack_adaptive(jnp.asarray(c), capacity_words=8 * 64)
    u = bitpack.unpack_adaptive(ap)
    assert (np.asarray(u) == c).all()


def test_adaptive_bits_follow_range(rng):
    c = np.zeros((4, 64), np.uint8)
    c[1] = rng.integers(0, 2, (64,))
    c[2] = rng.integers(0, 14, (64,))
    c[3] = rng.integers(0, 200, (64,))
    c[3, 0] = 199
    ap = bitpack.pack_adaptive(jnp.asarray(c), capacity_words=1024)
    bits = np.asarray(ap.bits)
    assert bits[0] == 1 and bits[1] == 1
    assert bits[2] == int(np.ceil(np.log2(c[2].max() + 1)))
    assert bits[3] == 8
    # deterministic offsets = exclusive cumsum of word counts
    assert (np.asarray(ap.offsets) == np.concatenate(
        [[0], np.cumsum(np.asarray(ap.nwords))[:-1]])).all()


def test_packed_words_vs_nostraddle():
    # straddle is denser, no-straddle is gather-free; both bounded
    for bits in range(1, 9):
        dense = bitpack.packed_words(1000, bits)
        loose = bitpack.nostraddle_words(1000, bits)
        assert dense <= loose <= dense + (1000 // (32 // bits)) + 1
