"""Sharded serving (DESIGN.md §12): greedy bit-identity vs the single-device
Server, arena-sharding introspection, and per-shard pool accounting.

Mesh tests run in subprocesses — the fake 4-device count must not leak into
other tests' jax runtime.  The ShardedPagedPool tests are pure host
bookkeeping and run in-process.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core.pool import PoolExhausted
from repro.distributed.serve_shard import ShardedPagedPool

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str) -> dict:
    prog = textwrap.dedent(code)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# ShardedPagedPool: host-side routing + accounting invariants (no mesh)
# ---------------------------------------------------------------------------


def test_sharded_pool_routing_and_offsets():
    pool = ShardedPagedPool(8, (16, 16), n_shards=2)
    assert pool.per_shard == 4
    a = pool.alloc(2, shard=0)
    b = pool.alloc(2, shard=1)
    # shard d hands out ids from [d * per_shard, (d+1) * per_shard)
    assert all(0 <= p < 4 for p in a), a
    assert all(4 <= p < 8 for p in b), b
    assert [pool.shard_of(p) for p in a + b] == [0, 0, 1, 1]
    # retain/release route by page id to the owning shard
    pool.retain(b)
    assert pool.refcount(b[0]) == 2
    assert pool.release(b) == []          # still referenced once
    assert sorted(pool.release(b)) == sorted(b)
    assert pool.shards[1].free_pages == 4
    assert pool.shards[0].free_pages == 2


def test_sharded_pool_aggregate_equals_shard_sum():
    import random

    rng = random.Random(0)
    pool = ShardedPagedPool(12, (8,), n_shards=4)
    live: list[int] = []
    for _ in range(200):
        op = rng.random()
        if op < 0.5:
            shard = rng.randrange(4)
            if pool.shards[shard].free_pages:
                live.extend(pool.alloc(1, shard=shard))
        elif op < 0.75 and live:
            pool.retain([rng.choice(live)])
        elif live:
            p = live.pop(rng.randrange(len(live)))
            pool.release([p])
        # the §12 invariant: aggregate accounting == sum over shards
        assert pool.free_pages == sum(s.free_pages for s in pool.shards)
        assert pool.live_pages == sum(s.live_pages for s in pool.shards)
        st = pool.stats()
        per = pool.shard_stats()
        assert st["pages_live"] == sum(p["pages_live"] for p in per)
        for p in per:
            assert p["pages_live"] + p["pages_free"] == pool.per_shard


def test_sharded_pool_shard_exhaustion_is_local():
    pool = ShardedPagedPool(8, (8,), n_shards=2)
    pool.alloc(4, shard=0)
    with pytest.raises(PoolExhausted):
        pool.alloc(1, shard=0)            # shard 0 dry...
    assert pool.free_pages == 4           # ...while shard 1 is untouched
    assert pool.alloc(4, shard=1)


def test_sharded_pool_rejects_uneven_split():
    with pytest.raises(ValueError):
        ShardedPagedPool(7, (8,), n_shards=2)


# ---------------------------------------------------------------------------
# Mesh parity: sharded greedy == single-device greedy, bit for bit
# ---------------------------------------------------------------------------

_PARITY_PROG = """
        import json, dataclasses
        import numpy as np, jax
        from repro import api
        from repro.models import model as M, registry
        from repro.launch.mesh import make_serve_mesh

        cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                                  cache_layout={layout!r})
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        # heterogeneous rows: prompts 36/28/22/18 tokens, budgets 7/6/4/3
        shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        work = []
        for i, (plen, n_new) in enumerate([(36, 7), (28, 6), (22, 4), (18, 3)]):
            tail = rng.integers(0, cfg.vocab_size, plen - 16).astype(np.int32)
            work.append((np.concatenate([shared, tail]), n_new))

        def run(mesh):
            server = api.serve(cfg, params, max_slots=4, max_seq=96,
                               q_chunk=32, kv_chunk=32, mesh=mesh, {extra})
            handles = [server.submit(api.Request(prompt=p, max_new_tokens=n))
                       for p, n in work]
            server.run()
            return server, [h.result().tokens.tolist() for h in handles]

        _, base = run(None)
        sserver, shard = run(make_serve_mesh("2,2"))
        out = {{"match": base == shard, "base": base, "shard": shard}}

        def norm(e):
            # GSPMD round-trips may express a spec entry as a 1-tuple
            return e[0] if isinstance(e, (tuple, list)) and len(e) == 1 else e
"""


@pytest.mark.parametrize("layout", ["raw", "packed", "kivi", "huffman"])
def test_sharded_serve_dense_bit_identical(layout):
    res = run_sub(_PARITY_PROG.format(layout=layout, extra="") + """
        print(json.dumps(out))
    """)
    assert res["match"], res


def test_sharded_serve_paged_prefix_bit_identical_and_arena_sharded():
    res = run_sub(_PARITY_PROG.format(
        layout="packed", extra='cache_mode="paged", prefix_cache="on"') + """
        # the arena page axis must be GENUINELY sharded over "data" and the
        # KV-head axis over "model" on the live stacked state
        kv = sserver.state["kv"]
        spec = tuple(norm(e) for e in kv.k_store.sharding.spec)
        # stacked paged store: [L, 1, Hkv, P, ...] -> heads@2, pages@3
        out["k_store_spec"] = [str(e) for e in spec]
        out["spec_ok"] = (len(spec) > 3 and spec[2] == "model"
                          and spec[3] == "data")
        P_glob = kv.spec.pool_pages
        shapes = {tuple(s.data.shape) for s in kv.k_store.addressable_shards}
        out["n_device_shards"] = len(kv.k_store.addressable_shards)
        out["local_pages_ok"] = all(s[3] == P_glob // 2 for s in shapes)
        out["local_heads_ok"] = all(s[2] == kv.k_buf.shape[2] // 2
                                    for s in shapes)
        # page-table rows shard on batch (stacked: [L, B, NB] -> "data"@1)
        pt_spec = tuple(norm(e) for e in kv.page_tab.sharding.spec)
        out["pt_ok"] = len(pt_spec) > 1 and pt_spec[1] == "data"
        # per-shard accounting: aggregate == sum over shards
        pool = sserver.pool
        out["pool_sum_ok"] = (
            pool.free_pages == sum(s.free_pages for s in pool.shards)
            and pool.live_pages == sum(s.live_pages for s in pool.shards))
        out["prefix_hits"] = sserver.stats()["prefix"]["hits"]
        print(json.dumps(out))
    """)
    assert res["match"], res
    assert res["spec_ok"], res
    assert res["n_device_shards"] == 4, res
    assert res["local_pages_ok"] and res["local_heads_ok"], res
    assert res["pt_ok"], res
    assert res["pool_sum_ok"], res
    assert res["prefix_hits"] > 0, res


def test_sharded_serve_pure_data_mesh_paged():
    # (4, 1) mesh: model axis 1 must be fine even though Hkv=2 < 4 devices
    res = run_sub("""
        import json, dataclasses
        import numpy as np, jax
        from repro import api
        from repro.models import model as M, registry
        from repro.launch.mesh import make_serve_mesh

        cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                                  cache_layout="packed")
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        work = [(rng.integers(0, cfg.vocab_size, 24 - 4 * i).astype(np.int32),
                 3 + i) for i in range(4)]

        def run(mesh):
            server = api.serve(cfg, params, max_slots=4, max_seq=64,
                               q_chunk=32, kv_chunk=32, cache_mode="paged",
                               mesh=mesh)
            hs = [server.submit(api.Request(prompt=p, max_new_tokens=n))
                  for p, n in work]
            server.run()
            return server, [h.result().tokens.tolist() for h in hs]

        _, base = run(None)
        sserver, shard = run(make_serve_mesh("4,1"))
        st = sserver.stats()["shards"]
        print(json.dumps({"match": base == shard, "n_data": st["n_data"],
                          "n_shards": len(st["per_shard"])}))
    """)
    assert res["match"], res
    assert res["n_data"] == 4 and res["n_shards"] == 4, res


def test_validate_serve_mesh_errors():
    res = run_sub("""
        import json, dataclasses, jax
        import numpy as np
        from repro.models import registry
        from repro.distributed import serve_shard
        from repro.launch.mesh import make_serve_mesh

        cfg = registry.get_smoke_config("yi_6b")
        errs = {}
        mesh = make_serve_mesh("1,4")      # model=4 does not divide Hkv=2
        try:
            serve_shard.validate_serve_mesh(mesh, cfg, 4)
        except ValueError as e:
            errs["kv_heads"] = "n_kv_heads" in str(e)
        try:
            serve_shard.validate_serve_mesh(make_serve_mesh("2,2"), cfg, 3)
        except ValueError as e:
            errs["slots"] = "max_slots" in str(e)
        wrong = jax.sharding.Mesh(
            np.asarray(jax.devices()).reshape(4), ("pod",))
        try:
            serve_shard.validate_serve_mesh(wrong, cfg, 4)
        except ValueError as e:
            errs["axes"] = "make_serve_mesh" in str(e)
        errs["ok"] = serve_shard.validate_serve_mesh(
            make_serve_mesh("2,2"), cfg, 4) == (2, 2)
        print(json.dumps(errs))
    """)
    assert res == {"kv_heads": True, "slots": True, "axes": True, "ok": True}, res


def test_chunked_admission_pages_stay_shard_affine():
    """Satellite (DESIGN.md §13): chunked admission on the sharded paged
    pool must keep every PREFILLING row's pages inside its own data
    shard's page range at every step — a chunk page that crossed shards
    would gather from another device's arena slice.  Checked step-wise
    while prefills are in flight, plus greedy parity vs unsharded."""
    res = run_sub("""
        import json, dataclasses
        import numpy as np, jax
        from repro import api
        from repro.models import model as M, registry
        from repro.launch.mesh import make_serve_mesh

        cfg = dataclasses.replace(registry.get_smoke_config("yi_6b"),
                                  cache_layout="packed")
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        T = M.cache_specs(cfg, 96)[0].block_size
        rng = np.random.default_rng(5)
        work = [(rng.integers(0, cfg.vocab_size, 5 * T + 3).astype(np.int32),
                 4 + i) for i in range(4)]

        def run(mesh):
            server = api.serve(cfg, params, max_slots=4, max_seq=96,
                               q_chunk=32, kv_chunk=32, cache_mode="paged",
                               mesh=mesh, prefill_chunk_tokens=T)
            hs = [server.submit(api.Request(prompt=p, max_new_tokens=n))
                  for p, n in work]
            affine, saw_prefilling = True, 0
            while server.active or server.pending or server.prefilling:
                server.step()
                saw_prefilling += server.prefilling
                if mesh is None:
                    continue  # the plain pool has no shard ranges
                for row in range(4):
                    want = server._row_shard(row)
                    for p in server._pt_host[row]:
                        if p >= 0 and server.pool.shard_of(int(p)) != want:
                            affine = False
            return (server, [h.result().tokens.tolist() for h in hs],
                    affine, saw_prefilling)

        _, base, _, _ = run(None)
        srv, shard, affine, saw = run(make_serve_mesh("4,1"))
        pf = srv.stats()["prefill"]
        print(json.dumps({"match": base == shard, "affine": affine,
                          "saw_prefilling": saw, "mode": pf["mode"],
                          "chunks": pf["chunks"],
                          "coscheduled": pf["coscheduled_tokens"]}))
    """)
    assert res["match"], res
    assert res["affine"], res
    # the 5-block prompts genuinely chunked across steps on the mesh path
    assert res["saw_prefilling"] > 0 and res["chunks"] >= 4 * 5, res
    assert res["mode"] == "chunked", res
