"""Checkpoint store: roundtrip, atomic commit, async writer, gc, and
bit-exact training resume."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def _tree(rng):
    return {
        "a": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (3,))),
                   "c": jnp.asarray(rng.normal(size=(2, 2))).astype(jnp.bfloat16)},
        "scalar": jnp.asarray(3, jnp.int32),
    }


def test_roundtrip(tmp_path, rng):
    t = _tree(rng)
    store.save(tmp_path, 7, t, meta={"note": "x"})
    restored, manifest = store.restore(tmp_path, t)
    assert manifest["step"] == 7 and manifest["meta"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path, rng):
    t = _tree(rng)
    for s in (1, 5, 3, 9):
        store.save(tmp_path, s, t)
    assert store.latest_step(tmp_path) == 9
    store.gc_old(tmp_path, keep=2)
    assert store.latest_step(tmp_path) == 9
    remaining = sorted(p.name for p in tmp_path.iterdir())
    assert remaining == ["step_000005", "step_000009"]


def test_tmp_dirs_ignored_and_cleaned(tmp_path, rng):
    t = _tree(rng)
    store.save(tmp_path, 2, t)
    # simulate a crash mid-write
    (tmp_path / "step_000099.tmp").mkdir()
    assert store.latest_step(tmp_path) == 2
    store.gc_old(tmp_path, keep=3)
    assert not (tmp_path / "step_000099.tmp").exists()


def test_async_checkpointer(tmp_path, rng):
    t = _tree(rng)
    ck = store.AsyncCheckpointer(tmp_path, keep=2)
    for s in (10, 20, 30):
        ck.save(s, t)
    ck.wait()
    assert store.latest_step(tmp_path) == 30
    restored, _ = store.restore(tmp_path, t)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(restored["a"]))


def test_restore_with_resharding(tmp_path, rng):
    t = _tree(rng)
    store.save(tmp_path, 1, t)
    from repro.distributed.sharding import make_mesh
    mesh = make_mesh((1,), ("d",))
    shardings = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()), t)
    restored, _ = store.restore(tmp_path, t, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(restored["a"]))


def test_training_resume_bit_exact(tmp_path):
    """6 straight steps == 3 steps + checkpoint + restore + 3 steps."""
    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.optim import adamw
    from repro.train import step as step_lib
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.get_smoke_config("qwen3_1_7b")
    data = SyntheticCorpus(seq_len=32, global_batch=2, vocab_size=cfg.vocab_size)
    mesh = make_host_mesh()
    scfg = step_lib.TrainStepConfig(
        remat=False, q_chunk=32, kv_chunk=32,
        opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=6))

    def make(dirname, total):
        return Trainer(cfg, mesh, scfg,
                       TrainerConfig(total_steps=total, ckpt_every=3,
                                     ckpt_dir=str(tmp_path / dirname),
                                     log_every=0),
                       data)

    tA = make("a", 6)
    outA = tA.run()
    tB1 = make("b", 3)
    tB1.run()
    tB2 = make("b", 6)
    assert tB2.maybe_resume()
    assert tB2.start_step == 3
    outB = tB2.run()
    assert abs(outA["last_loss"] - outB["last_loss"]) < 1e-5
