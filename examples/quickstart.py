"""Quickstart: KVComp in five minutes, on CPU.

Everything goes through the public facade::

    from repro import api
    from repro.core.policy import CompressionPolicy, TensorPolicy

    policy = CompressionPolicy(layout="packed")       # raw|packed|kivi|huffman
    cache  = api.compress(k, v, policy=policy)        # Store (bulk prefill)
    cache  = api.append(cache, k_new, v_new)          # Store (decode append)
    out    = api.attend(cache, q)                     # Fetch (fused algebra)
    k2, v2 = api.decompress(cache)                    # reconstruct
    report = api.estimate_ratio(k, v, policy=policy)  # exact size accounting
    api.available_layouts()                           # registry contents

This script walks:

1.  Quantize + entropy-code a KV tensor, print the ratio accounting.
2.  Build a compressed KV cache, append tokens, attend — and compare with
    exact attention — for every registered layout.
3.  Run the fused Pallas kernel (interpret mode) against its oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import cache as kvcache
from repro.core.policy import CompressionPolicy, TensorPolicy
from repro.kernels import ops

rng = np.random.default_rng(0)

# --- 1. compress a KV tensor ------------------------------------------------
print("=== 1. quantize + entropy-code ===")
# heavy-tailed synthetic KV (LLM-like statistics)
k = jnp.asarray((rng.standard_t(3, (1024, 8, 64)) * 0.5).astype(np.float32))
v = jnp.asarray((rng.standard_t(3, (1024, 8, 64)) * 0.5).astype(np.float32))

for layout in api.available_layouts():
    r = api.estimate_ratio(k, v, policy=CompressionPolicy(
        layout=layout, block_size=64,
        k=TensorPolicy(rel_scale=0.05), v=TensorPolicy(rel_scale=0.15)))
    print(f"  {layout:8s}: ratio {r['ratio']:5.2f}x  "
          f"(K {r['k'].bits_per_value:.2f} / V {r['v'].bits_per_value:.2f} "
          f"bits/value incl. metadata)")

# --- 2. the growing compressed cache -----------------------------------------
print("=== 2. compressed KV cache (prefill + append + attend) ===")
B, Hkv, S, D = 2, 4, 200, 64
kc = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
vc = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
q = jnp.asarray(rng.normal(size=(B, Hkv * 2, D)).astype(np.float32))


def policy(layout):
    return CompressionPolicy(layout=layout, block_size=32,
                             k=TensorPolicy(rel_scale=0.05),
                             v=TensorPolicy(rel_scale=0.15))


# decode-time natural appending (paper §3.2.3): same 3 tokens every layout
k_new = jnp.asarray(rng.normal(size=(3, B, Hkv, D)), jnp.float32)
v_new = jnp.asarray(rng.normal(size=(3, B, Hkv, D)), jnp.float32)
k_full = jnp.concatenate([kc, k_new.transpose(1, 2, 0, 3)], axis=2)
v_full = jnp.concatenate([vc, v_new.transpose(1, 2, 0, 3)], axis=2)
ref = kvcache.reference_attend(k_full, v_full, q)

caches = {}
for layout in api.available_layouts():
    cache = api.compress(kc, vc, policy=policy(layout), max_seq=512)
    for t in range(3):
        cache = api.append(cache, k_new[t], v_new[t])
    out = api.attend(cache, q)
    err = float(jnp.max(jnp.abs(out - ref)))
    nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    caches[layout] = (cache, nbytes)
    print(f"  [{layout:8s}] total_len={int(cache.total_len[0])}  "
          f"cache bytes={nbytes:>9,}  attend |Δ| vs exact={err:.3f}")

bytes_raw = caches["raw"][1]
for layout, (_, nbytes) in caches.items():
    if layout != "raw":
        print(f"  {layout:8s} vs raw allocation: {bytes_raw / nbytes:.2f}x smaller")

# --- 3. fused kernel (cache-resident decompression) --------------------------
print("=== 3. fused Pallas kernel vs XLA oracle ===")
cache = caches["packed"][0]
o_pallas = ops.cache_decode_attention(cache, q, impl="pallas")
o_xla = ops.cache_decode_attention(cache, q, impl="xla")
print(f"  pallas-vs-xla max diff: {float(jnp.max(jnp.abs(o_pallas - o_xla))):.2e}")
print("done.")
