"""Quickstart: KVComp in five minutes, on CPU.

1.  Quantize + entropy-code a KV tensor, print the ratio accounting.
2.  Build a compressed KV cache, append tokens, attend — and compare with
    exact attention.
3.  Run the fused Pallas kernel (interpret mode) against its oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as kvcache
from repro.core import quant
from repro.core.codec import KVCompCodec
from repro.kernels import ops

rng = np.random.default_rng(0)

# --- 1. compress a KV tensor ------------------------------------------------
print("=== 1. quantize + entropy-code ===")
# heavy-tailed synthetic KV (LLM-like statistics)
k = jnp.asarray((rng.standard_t(3, (1024, 8, 64)) * 0.5).astype(np.float32))
v = jnp.asarray((rng.standard_t(3, (1024, 8, 64)) * 0.5).astype(np.float32))

codec = KVCompCodec(quant.QuantConfig(block_size=64, rel_scale_k=0.05,
                                      rel_scale_v=0.15))
codec.fit(k, v)  # per-layer shared Huffman codebooks (paper §3.2)
qk = codec.quantize_k(k)
for mode in ("huffman", "packed", "kivi"):
    r = codec.report_k(qk, mode)
    print(f"  K {mode:8s}: ratio {r.ratio:5.2f}x  "
          f"({r.bits_per_value:.2f} bits/value incl. metadata)")
err = float(jnp.max(jnp.abs(qk.dequantize().reshape(k.shape) - k)))
print(f"  max abs error: {err:.4f} (error-bounded: step = rel x (max-min))")

# --- 2. the growing compressed cache -----------------------------------------
print("=== 2. compressed KV cache (prefill + append + attend) ===")
spec = kvcache.CacheSpec(layout="packed", block_size=32, max_seq=512,
                         rel_scale_k=0.05, rel_scale_v=0.15)
B, Hkv, S, D = 2, 4, 200, 64
kc = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
vc = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
cache = kvcache.prefill(spec, kc, vc)
print(f"  prefilled {S} tokens -> {int(cache.n_flushed)} compressed blocks "
      f"+ {int(cache.buf_len)} raw-buffer tokens")
for _ in range(3):  # decode-time natural appending (paper §3.2.3)
    cache = kvcache.append(cache,
                           jnp.asarray(rng.normal(size=(B, Hkv, D)), jnp.float32),
                           jnp.asarray(rng.normal(size=(B, Hkv, D)), jnp.float32))
print(f"  after 3 appends: total_len={int(cache.total_len)}")
q = jnp.asarray(rng.normal(size=(B, Hkv * 2, D)).astype(np.float32))
out = kvcache.attend(cache, q)
print(f"  attend -> {out.shape}, finite: {bool(jnp.isfinite(out).all())}")

bytes_packed = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
raw_cache = kvcache.prefill(dataclasses.replace(spec, layout="raw"), kc, vc)
bytes_raw = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(raw_cache))
print(f"  cache bytes: raw {bytes_raw:,} -> packed {bytes_packed:,} "
      f"({bytes_raw / bytes_packed:.2f}x smaller)")

# --- 3. fused kernel (cache-resident decompression) --------------------------
print("=== 3. fused Pallas kernel vs XLA oracle ===")
o_pallas = ops.cache_decode_attention(cache, q, impl="pallas")
o_xla = ops.cache_decode_attention(cache, q, impl="xla")
print(f"  pallas-vs-xla max diff: {float(jnp.max(jnp.abs(o_pallas - o_xla))):.2e}")
print("done.")
