"""End-to-end serving driver (the paper's deployment scenario): train a
small LM, then serve batched requests with every registered cache layout —
comparing generated text, cache memory, and decode throughput.

Layouts come from the ``repro.api`` registry, so a newly registered layout
shows up in this comparison with no changes here.

    PYTHONPATH=src python examples/serve_compressed.py
"""

import dataclasses
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.models import model as M
from repro.serve.engine import Engine, EngineConfig, Request, cache_memory_report


def main():
    cfg, params, data = common.get_tiny_lm()
    prompts = [data.batch_at(900 + i)["tokens"][0][:64].astype(np.int32)
               for i in range(4)]

    # raw first: it is the exactness baseline the others are compared to
    order = ["raw"] + [n for n in api.available_layouts() if n != "raw"]
    results = {}
    for layout in order:
        c = dataclasses.replace(cfg, cache_layout=layout)
        eng = Engine(c, params, EngineConfig(bucket=64, max_batch=4, max_seq=256),
                     q_chunk=64, kv_chunk=64)
        t0 = time.monotonic()
        outs = eng.generate([Request(prompt=p, max_new_tokens=24)
                             for p in prompts])
        dt = time.monotonic() - t0
        _, state = M.prefill(params, c, {"tokens": np.stack(prompts)}, 256,
                             q_chunk=64, kv_chunk=64)
        rep = cache_memory_report(c, state)
        results[layout] = (outs, dt, rep)
        tput = sum(24 / r.gen_s for r in outs)
        print(f"[{layout:8s}] kv_cache={rep['kv_bytes']:>9,}B  "
              f"wall={dt:5.2f}s  decode={tput:6.1f} tok/s")

    raw_toks = [r.tokens for r in results["raw"][0]]
    for layout in order[1:]:
        toks = [r.tokens for r in results[layout][0]]
        agree = np.mean([(a == b).mean() for a, b in zip(raw_toks, toks)])
        saved = 1 - results[layout][2]["kv_bytes"] / results["raw"][2]["kv_bytes"]
        print(f"{layout:8s} vs raw: token agreement {agree:5.1%}, "
              f"cache memory saved {saved:5.1%}")

    # show a decoded sample (byte-level -> printable text)
    txt = bytes(int(t) for t in raw_toks[0]).decode("utf8", errors="replace")
    print(f"sample continuation (raw): {txt!r}")
    txt = bytes(int(t) for t in results["packed"][0][0].tokens).decode(
        "utf8", errors="replace")
    print(f"sample continuation (kvcomp): {txt!r}")


if __name__ == "__main__":
    main()
