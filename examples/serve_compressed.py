"""End-to-end serving driver (the paper's deployment scenario): train a
small LM, then serve a mixed batch of requests with every registered cache
layout through the continuous-batching ``api.serve`` Server — comparing
generated text, cache memory, and decode throughput.

Layouts come from the ``repro.api`` registry, so a newly registered layout
shows up in this comparison with no changes here.

    PYTHONPATH=src python examples/serve_compressed.py

Self-contained: only ``repro.*`` imports (no repo-root ``benchmarks``
package), so ``PYTHONPATH=src`` alone suffices.  The tiny byte-level LM it
serves comes from ``repro.launch.tiny_lm`` — the single definition the
benchmarks also use, sharing one ``artifacts/tiny_lm`` checkpoint so
neither entry point retrains after the other.
"""

import dataclasses
import time

import numpy as np

from repro import api
from repro.launch.tiny_lm import get_tiny_lm


def main():
    cfg, params, data = get_tiny_lm()
    prompts = [data.batch_at(900 + i)["tokens"][0][:64].astype(np.int32)
               for i in range(4)]

    # raw first: it is the exactness baseline the others are compared to
    order = ["raw"] + [n for n in api.available_layouts() if n != "raw"]
    results = {}
    for layout in order:
        c = dataclasses.replace(cfg, cache_layout=layout)
        server = api.serve(c, params, max_slots=4, max_seq=256,
                           q_chunk=64, kv_chunk=64)
        handles = [server.submit(api.Request(prompt=p, max_new_tokens=24))
                   for p in prompts]
        t0 = time.monotonic()
        server.run()
        dt = time.monotonic() - t0
        outs = [h.result() for h in handles]
        rep = server.memory_report()
        results[layout] = (outs, dt, rep)
        # aggregate decode throughput: per-request decode rates summed
        # (requests decode concurrently; wall would fold prefill in)
        tput = sum(len(r.tokens) / r.gen_s for r in outs if r.gen_s > 0)
        print(f"[{layout:8s}] kv_cache={rep['kv_bytes']:>9,}B  "
              f"wall={dt:5.2f}s  decode={tput:6.1f} tok/s")

    raw_toks = [r.tokens for r in results["raw"][0]]
    for layout in order[1:]:
        toks = [r.tokens for r in results[layout][0]]
        agree = np.mean([(a == b).mean() for a, b in zip(raw_toks, toks)])
        saved = 1 - results[layout][2]["kv_bytes"] / results["raw"][2]["kv_bytes"]
        print(f"{layout:8s} vs raw: token agreement {agree:5.1%}, "
              f"cache memory saved {saved:5.1%}")

    # show a decoded sample (byte-level -> printable text)
    txt = bytes(int(t) for t in raw_toks[0]).decode("utf8", errors="replace")
    print(f"sample continuation (raw): {txt!r}")
    txt = bytes(int(t) for t in results["packed"][0][0].tokens).decode(
        "utf8", errors="replace")
    print(f"sample continuation (kvcomp): {txt!r}")

    # paged block pool (DESIGN.md §10): same workload through the shared
    # arena — admission is bounded by compressed bytes, not slot count, and
    # stats() exposes the pool occupancy the scheduler admits against.
    c = dataclasses.replace(cfg, cache_layout="packed")
    server = api.serve(c, params, max_slots=len(prompts), max_seq=256,
                       cache_mode="paged", q_chunk=64, kv_chunk=64)
    handles = [server.submit(api.Request(prompt=p, max_new_tokens=24))
               for p in prompts]
    server.run()
    paged_toks = [h.result().tokens for h in handles]
    agree = np.mean([(r.tokens == t).mean()
                     for r, t in zip(results["packed"][0], paged_toks)])
    print(f"[paged   ] packed tokens agree with dense: {agree:5.1%}")
    # One schema, one printer (DESIGN.md §14): stats() is the registry
    # snapshot and format_snapshot the shared renderer — pool occupancy,
    # shard pressure, and latency quantiles in the documented layout.
    print(api.obs.format_snapshot(server.stats()))


if __name__ == "__main__":
    main()
