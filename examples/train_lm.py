"""End-to-end training driver: train a byte-level LM on real on-disk text
with the full production stack (sharded step, AdamW, checkpointing,
straggler monitor, preemption handling), then resume from the checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

This is the CPU-scale version of the production path; the same Trainer and
step builder drive the full configs on the 16x16 mesh (launch/train.py
--production).
"""

import argparse
import tempfile

from repro.data.pipeline import TextCorpus
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.optim import adamw
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="kvcomp_train_")

    cfg = registry.get_smoke_config("llama2_7b")
    import dataclasses

    cfg = dataclasses.replace(cfg, vocab_size=256, d_model=128, n_layers=2,
                              n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256)
    data = TextCorpus(seq_len=128, global_batch=8, max_bytes=2 << 20)
    scfg = step_lib.TrainStepConfig(
        remat=True, microbatches=2, q_chunk=128, kv_chunk=128,
        opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=args.steps // 10,
                              total_steps=args.steps))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.steps // 2,
                         ckpt_dir=ckpt_dir, log_every=20)
    trainer = Trainer(cfg, make_host_mesh(), scfg, tcfg, data)
    trainer.install_signal_handlers()
    summary = trainer.run()
    print("first run:", summary)

    # demonstrate checkpoint/restart: extend training from the checkpoint
    trainer2 = Trainer(cfg, make_host_mesh(), scfg,
                       TrainerConfig(total_steps=args.steps + 20,
                                     ckpt_every=0, ckpt_dir=ckpt_dir,
                                     log_every=20),
                       data)
    assert trainer2.maybe_resume(), "expected a checkpoint to resume from"
    print(f"resumed from step {trainer2.start_step}")
    print("second run:", trainer2.run())


if __name__ == "__main__":
    main()
