"""StableLM-2-12B [hf:stabilityai; hf] — dense GQA, head_dim 160."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    rope_theta=1e4,
)
