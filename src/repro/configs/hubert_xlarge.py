"""HuBERT-XLarge [arXiv:2106.07447; unverified] — encoder-only audio
transformer (w2v2 architecture).  The CNN waveform frontend is a stub:
``input_specs`` provides precomputed frame embeddings [B, S, d].  No
autoregressive decode → no KV cache → decode/long shapes are skipped
(DESIGN.md §4); the 504-way head mirrors the cluster-prediction task."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="dense",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    causal=False,
    input_mode="embeddings",
    rope_theta=1e4,
)
