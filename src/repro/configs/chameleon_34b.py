"""Chameleon-34B [arXiv:2405.09818; unverified] — early-fusion VLM backbone.

VQ image tokens share the 65536-entry unified vocabulary with text, so the
backbone is a dense GQA decoder; the image tokenizer frontend is a stub
(``input_specs`` feeds token ids / precomputed embeddings).  Chameleon uses
qk-norm for training stability."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    rope_theta=1e4,
)
