"""Ministral-8B-shape config (paper evaluation model, §4.1) — GQA + SWA."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="ministral-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=131072,
    sliding_window=32768,
    rope_theta=1e8,
)
