"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified] — dense GQA,
no biases, large 256k vocabulary (embedding table dominates memory)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    tie_embeddings=True,
    rope_theta=8e6,
)
