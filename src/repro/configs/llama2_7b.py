"""Llama2-7B-shape config (paper evaluation model, §4.1)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
)
