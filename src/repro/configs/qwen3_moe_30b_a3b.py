"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf] — 128-expert top-8 fine-grained
MoE with qk-norm.  Expert axis ≥ |model| mesh axis → true expert parallelism."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    d_ff_expert=768,
    n_experts=128,
    top_k=8,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)
