"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone with SHARED
attention blocks every 6th position (81 blocks: 13×(5 mamba + 1 shared attn)
+ 3 tail mamba).  Attention layers carry compressed KV caches; mamba layers
carry constant-size state → hybrid long_500k runs."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    hybrid_period=6,
    rope_theta=1e4,
)
