"""Mixtral-8x22B [arXiv:2401.04088; hf] — 8-expert top-2 MoE with
sliding-window attention (window 4096, as in Mixtral v0.1's SWA lineage).
SWA makes long_500k decode sub-quadratic: the cache ring holds only the
window, evicting whole compression blocks (DESIGN.md §4)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    d_ff_expert=16384,
    n_experts=8,
    top_k=2,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1e6,
)
