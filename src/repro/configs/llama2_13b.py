"""Llama2-13B-shape config (paper evaluation model, §4.1)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=13824,
    vocab_size=32000,
)
