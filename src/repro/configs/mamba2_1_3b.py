"""Mamba2-1.3B [arXiv:2405.21060; unverified] — attention-free SSD.

No KV cache exists, so the paper's technique is inapplicable (DESIGN.md §4
"Arch-applicability"); the arch is implemented without it and long_500k runs
natively on the constant-size state."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
)
