"""Deterministic, resumable data pipeline.

Two sources, one interface:

* ``TextCorpus`` — byte-level LM data harvested from on-disk text (Python
  sources/docs in the environment), used by the accuracy experiments so the
  KV statistics come from a *real* language distribution, not noise.
* ``SyntheticCorpus`` — Zipfian token streams with arbitrary vocab, used by
  throughput benchmarks and smoke tests.

Determinism/resume contract: ``batch_at(step)`` is a pure function of
(seed, step) — a restarted job reading step k produces bit-identical batches
(no iterator state to checkpoint), and different data-parallel hosts slice
disjoint shards of each batch.
"""

from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path

import numpy as np


def harvest_text(max_bytes: int = 4 << 20) -> bytes:
    """Deterministically harvest real text from the installed Python tree."""
    import email
    import json as _json

    roots = []
    for mod in (email, _json):
        roots.append(Path(mod.__file__).parent)
    import jax

    roots.append(Path(jax.__file__).parent / "_src")
    files = []
    for root in roots:
        files.extend(sorted(root.rglob("*.py")))
    buf = bytearray()
    for f in files:
        try:
            buf.extend(f.read_bytes())
        except OSError:
            continue
        if len(buf) >= max_bytes:
            break
    return bytes(buf[:max_bytes])


@dataclasses.dataclass
class TextCorpus:
    """Byte-level corpus: vocab = 256."""

    seq_len: int
    global_batch: int
    seed: int = 0
    max_bytes: int = 4 << 20
    vocab_size: int = 256

    def __post_init__(self):
        data = np.frombuffer(harvest_text(self.max_bytes), np.uint8)
        self._data = data
        self._n_windows = (len(data) - 1) // self.seq_len

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step): stateless resume."""
        rng = np.random.default_rng(
            int.from_bytes(hashlib.sha256(f"{self.seed}:{step}".encode()).digest()[:8], "little"))
        idx = rng.integers(0, self._n_windows, size=self.global_batch)
        starts = idx * self.seq_len
        tok = np.stack([self._data[s : s + self.seq_len] for s in starts]).astype(np.int32)
        lab = np.stack([self._data[s + 1 : s + 1 + self.seq_len] for s in starts]).astype(np.int32)
        return {"tokens": tok, "labels": lab}

    def host_shard(self, batch: dict, host_id: int, n_hosts: int) -> dict:
        per = self.global_batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in batch.items()}


@dataclasses.dataclass
class SyntheticCorpus:
    """Zipfian ids for arbitrary vocab sizes (benchmarks/smokes)."""

    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            int.from_bytes(hashlib.sha256(f"{self.seed}:{step}".encode()).digest()[:8], "little"))
        shape = (self.global_batch, self.seq_len + 1)
        raw = rng.zipf(self.zipf_a, size=shape)
        ids = (raw % self.vocab_size).astype(np.int32)
        return {"tokens": ids[:, :-1], "labels": ids[:, 1:]}
