"""Trainer: the fault-tolerant training loop.

Production behaviors implemented (and unit-tested):

* checkpoint/restart — periodic async checkpoints; on start, automatic
  resume from the latest committed step (elastic: restored arrays are
  device_put against the *current* mesh's shardings);
* preemption handling — SIGTERM/SIGINT set a flag; the loop finishes the
  current step, writes a final checkpoint, and exits cleanly;
* straggler monitor — per-step wall time EWMA; steps slower than
  ``straggler_factor ×`` the EWMA are recorded (on real fleets this feeds
  the scheduler that re-slices stragglers; here it is surfaced in metrics);
* deterministic data resume — the pipeline is a pure function of step.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import store
from repro.distributed import sharding as shd
from repro.models.config import ModelConfig
from repro.optim import adamw, grad_compress
from repro.train import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.1


class StragglerMonitor:
    """Wall-time EWMA; flags slow steps.  ``clock`` is injectable for tests."""

    def __init__(self, factor: float, alpha: float, clock=time.monotonic):
        self.factor = factor
        self.alpha = alpha
        self.clock = clock
        self.ewma: float | None = None
        self.events: list[tuple[int, float, float]] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = self.clock()

    def stop(self, step: int) -> bool:
        dt = self.clock() - self._t0
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.events.append((step, dt, self.ewma))
        self.ewma = dt if self.ewma is None else (
            self.alpha * dt + (1 - self.alpha) * self.ewma)
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, scfg: step_lib.TrainStepConfig,
                 tcfg: TrainerConfig, data, init_key=None):
        self.cfg, self.mesh, self.scfg, self.tcfg, self.data = cfg, mesh, scfg, tcfg, data
        batch0 = data.batch_at(0)
        bspecs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch0.items()}
        step_fn, state_shapes, in_sh, out_sh = step_lib.build_train_artifacts(
            cfg, mesh, scfg, bspecs)
        self.in_sh = in_sh
        self.step_fn = jax.jit(step_fn, in_shardings=in_sh,
                               out_shardings=out_sh, donate_argnums=0)
        self.pshard, self.oshard, self.eshard = in_sh[0]
        self.bshard = in_sh[1]
        self.state = None
        self.start_step = 0
        self._preempted = False
        self.ckpt = store.AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.monitor = StragglerMonitor(tcfg.straggler_factor, tcfg.ewma_alpha)
        self.metrics_log: list[dict] = []
        self._init_key = init_key if init_key is not None else jax.random.PRNGKey(0)

    # -- state ---------------------------------------------------------------
    def init_state(self):
        from repro.models import layers as L
        from repro.models import model as M

        dtype = L.dtype_of(self.cfg.dtype)

        def init_all(k):
            params, _ = M.init_params(self.cfg, k, dtype)
            return params

        with self.mesh:
            params = jax.jit(init_all, out_shardings=self.pshard)(self._init_key)
            opt = jax.jit(adamw.init, out_shardings=self.oshard)(params)
            err = None
            if self.eshard is not None:
                err = jax.jit(grad_compress.init_error_state,
                              out_shardings=self.eshard)(params)
        self.state = (params, opt, err)

    def maybe_resume(self) -> bool:
        last = store.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        tmpl = (jax.eval_shape(lambda: self.state) if self.state is not None
                else None)
        if self.state is None:
            self.init_state()
        shardings = (self.pshard, self.oshard, self.eshard)
        # drop the None error slot from the tree when not in use
        tree_like = jax.tree.map(lambda x: x, self.state)
        restored, manifest = store.restore(
            self.tcfg.ckpt_dir, tree_like, step=last, shardings=shardings)
        self.state = restored
        self.start_step = int(manifest["step"])
        return True

    # -- preemption ----------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def request_preempt(self):
        self._preempted = True

    # -- loop ----------------------------------------------------------------
    def run(self) -> dict:
        if self.state is None and not self.maybe_resume():
            self.init_state()
        t_start = time.monotonic()
        step = self.start_step
        with self.mesh:
            while step < self.tcfg.total_steps and not self._preempted:
                batch_np = self.data.batch_at(step)
                batch = {k: jax.device_put(v, self.bshard[k])
                         for k, v in batch_np.items()}
                self.monitor.start()
                self.state, metrics = self.step_fn(self.state, batch)
                metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
                slow = self.monitor.stop(step)
                metrics.update(step=step, straggler=slow)
                self.metrics_log.append(metrics)
                if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                    print(f"step {step:6d} loss={metrics['loss']:.4f} "
                          f"lr={metrics['lr']:.2e} gnorm={metrics['grad_norm']:.3f}",
                          flush=True)
                step += 1
                if self.tcfg.ckpt_every and step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, self.state, {"wall_s": time.monotonic() - t_start})
        # final checkpoint (preemption or completion)
        self.ckpt.save(step, self.state, {"final": True})
        self.ckpt.wait()
        return {
            "final_step": step,
            "preempted": self._preempted,
            "straggler_events": list(self.monitor.events),
            "last_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
        }
