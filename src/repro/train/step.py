"""Distributed train/serve step builders.

``make_train_step`` returns a pjit-compiled step with:
  * FSDP×TP parameter shardings from the logical-axis rules,
  * optional gradient accumulation over microbatches (scan),
  * optional remat (activation checkpointing) of layer bodies,
  * optional cross-pod error-feedback gradient compression (shard_map over
    the "pod" axis with the in-pod axes left to the SPMD partitioner).

``make_serve_steps`` returns pjit'd (prefill, decode) closures over the
compressed-cache serving path.

Both builders can also return the *unjitted* step plus the sharding trees,
which is what launch/dryrun.py lowers against ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw, grad_compress


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: bool = True
    microbatches: int = 1
    q_chunk: int = 2048
    kv_chunk: int = 2048
    unroll: bool = False
    cross_pod_grad_compress: bool = False
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)


def shape_of(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def build_train_artifacts(cfg: ModelConfig, mesh: Mesh, scfg: TrainStepConfig,
                          batch_shape: dict[str, jax.ShapeDtypeStruct]):
    """Returns (step_fn, state_shapes, in_shardings, out_shardings).

    state = (params, opt_state); step(state, batch) -> (state, metrics).
    Everything is shape-only: the caller decides whether to init for real
    (training) or lower against ShapeDtypeStructs (dry-run).
    """
    rules = shd.train_rules(cfg, mesh)
    shd.set_ambient_mesh(mesh)  # enables activation constraints at trace time
    pshapes, axes = shapes_and_axes(cfg)
    pshard = shd.make_param_shardings(axes, pshapes, rules, mesh)
    ostate_shapes = jax.eval_shape(adamw.init, pshapes)
    oshard = adamw.AdamWState(
        step=shd.replicated(mesh), mu=pshard, nu=pshard)

    bshard = {k: shd.batch_sharding(mesh, v) for k, v in batch_shape.items()}

    err_shapes = None
    eshard = None
    if scfg.cross_pod_grad_compress and "pod" in mesh.axis_names:
        err_shapes = jax.eval_shape(grad_compress.init_error_state, pshapes)
        eshard = jax.tree.map(lambda s: s, pshard)  # error buf mirrors params

    def loss_fn(params, batch):
        loss, parts = M.lm_loss(
            params, cfg, batch, remat=scfg.remat,
            q_chunk=scfg.q_chunk, kv_chunk=scfg.kv_chunk, unroll=scfg.unroll)
        return loss, parts

    def grads_of(params, batch):
        if scfg.microbatches <= 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, parts, grads
        # gradient accumulation: split batch on the leading axis
        mb = scfg.microbatches
        da = shd.data_axes(mesh)

        def split(x):
            y = x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            # keep each microbatch slice sharded like the original batch —
            # otherwise SPMD falls back to full rematerialization
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, da, *([None] * (y.ndim - 2)))))

        mbatch = jax.tree.map(split, batch)

        def acc(carry, bi):
            g_sum, l_sum = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, bi)
            g_sum = jax.tree.map(jnp.add, g_sum, g)
            return (g_sum, l_sum + loss), None

        g0 = jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                jnp.zeros(x.shape, jnp.float32), s),
            params, pshard)
        (g_sum, l_sum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mbatch)
        grads = jax.tree.map(lambda g: (g / mb).astype(jnp.float32), g_sum)
        return l_sum / mb, {"aux_loss": jnp.zeros((), jnp.float32),
                            "ce": l_sum / mb}, grads

    def grads_pod_compressed(params, batch, err):  # pragma: no cover
        """Fully-manual pod-axis variant: computes grads with the pod axis
        MANUAL so the cross-pod all-reduce itself carries compressed data.
        BLOCKED upstream: XLA's SPMD partitioner CHECK-fails
        (spmd_partitioner_util.cc PartitionGather) when partitioning this
        model under a partial-auto shard_map on the host platform — the
        active path compresses after the in-pod reduction instead, which
        preserves the error-feedback numerics; the transport-level byte
        saving is accounted analytically in EXPERIMENTS.md §Perf."""
        from jax import shard_map

        def per_pod(params, batch, err):
            (loss, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            gq, e_new = grad_compress.tree_compress_decompress(g, err)
            g_red = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), gq)
            loss = jax.lax.pmean(loss, "pod")
            parts = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), parts)
            return loss, parts, g_red, e_new

        pspec = jax.tree.map(lambda _: P(), params)
        bspec = jax.tree.map(lambda _: P("pod"), batch)
        espec = jax.tree.map(lambda _: P(), err)
        parts_spec = {"aux_loss": P(), "ce": P()}
        fn = shard_map(per_pod, mesh=mesh,
                       in_specs=(pspec, bspec, espec),
                       out_specs=(P(), parts_spec, pspec, espec),
                       axis_names={"pod"}, check_vma=False)
        return fn(params, batch, err)

    def step(state, batch):
        params, opt_state, err = state
        loss, parts, grads = grads_of(params, batch)
        if err is not None:
            grads, err = _cross_pod_compressed_allreduce(grads, err, mesh, pshard)
        new_params, new_opt, metrics = adamw.update(scfg.opt, grads, opt_state, params)
        metrics = {"loss": loss, **parts, **metrics}
        return (new_params, new_opt, err), metrics

    state_shapes = (pshapes, ostate_shapes, err_shapes)
    in_shardings = ((pshard, oshard, eshard), bshard)
    out_shardings = ((pshard, oshard, eshard), None)  # metrics: XLA's choice
    return step, state_shapes, in_shardings, out_shardings


def _cross_pod_compressed_allreduce(grads, err, mesh: Mesh, pshard):
    """Error-feedback int8 compression on the pod axis (shard_map, other axes
    auto).  Gradients arrive already reduced over in-pod data axes by the
    SPMD partitioner; only the pod-axis reduction is intercepted here."""
    try:  # jax >= 0.6 top-level API
        from jax import shard_map
        sm_kwargs = dict(axis_names={"pod"}, check_vma=False)
    except ImportError:  # pinned 0.4.x: experimental home + auto/check_rep
        from jax.experimental.shard_map import shard_map
        sm_kwargs = dict(auto=frozenset(a for a in mesh.axis_names if a != "pod"),
                         check_rep=False)

    def per_pod(g_tree, e_tree):
        gq, e_new = grad_compress.tree_compress_decompress(g_tree, e_tree)
        g_red = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), gq)
        return g_red, e_new

    # Partial-manual shard_map: only "pod" is manual; in/out specs may refer
    # to manual axes only.  Gradients/error state are replicated across pods
    # (pure-DP pod axis), hence P() per leaf; in-pod (data/model) shardings
    # stay under the automatic partitioner.
    specs_g = jax.tree.map(lambda _: P(), pshard)
    fn = shard_map(
        per_pod, mesh=mesh,
        in_specs=(specs_g, specs_g), out_specs=(specs_g, specs_g),
        **sm_kwargs)
    return fn(grads, err)


def shapes_and_axes(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical-axes tree) without any allocation.

    ``init_params`` runs abstractly under eval_shape; the axes tree is pure
    Python built during tracing, captured by side effect.
    """
    from repro.models import layers as L

    box = {}
    dtype = L.dtype_of(cfg.dtype)

    def f(k):
        p, a = M.init_params(cfg, k, dtype)
        box["axes"] = a
        return p

    pshapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return pshapes, box["axes"]


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


def build_serve_artifacts(cfg: ModelConfig, mesh: Mesh, *, batch: int,
                          prompt_len: int, max_seq: int,
                          q_chunk: int = 2048, kv_chunk: int = 2048,
                          unroll: bool = False):
    """Returns dict with prefill/decode step fns + sharding trees."""
    rules = shd.serve_rules(cfg, mesh)
    shd.set_ambient_mesh(mesh)
    pshapes, axes = shapes_and_axes(cfg)
    pshard = shd.make_param_shardings(axes, pshapes, rules, mesh)

    state_shapes = jax.eval_shape(
        lambda: M.init_decode_state(cfg, batch, max_seq))
    sshard = shd.cache_shardings(state_shapes, mesh)

    def prefill_step(params, batch_in):
        logits, state = M.prefill(params, cfg, batch_in, max_seq,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk)
        return logits[:, -1], state

    def decode_step(params, tokens, position, state):
        return M.decode_step(params, cfg, tokens, position, state)

    return dict(
        prefill=prefill_step, decode=decode_step,
        pshapes=pshapes, pshard=pshard,
        state_shapes=state_shapes, sshard=sshard, rules=rules)
