"""Error-feedback gradient compression for the cross-pod all-reduce.

The paper's theme — error-bounded quantization + entropy-aware transport —
applied to *training* communication: gradients crossing the slow inter-pod
links are quantized to int8 with per-tensor scale and a persistent error-
feedback accumulator (the quantization residual is re-added next step, which
preserves convergence: Karimireddy et al., "Error Feedback Fixes SignSGD").

Usage (inside a shard_map over the "pod" axis, other axes auto):

    g_c, err = compress_decompress(g, err)         # local, error-feedback
    g = jax.lax.pmean(g_c, "pod")                   # 8x fewer DCN bytes*

(*the int8 payload is what a real DCN transport would move; under XLA's
host-platform simulation the collective still moves the dequantized f32 —
byte accounting for the roofline uses the int8 payload size.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array, err: jax.Array):
    """One error-feedback round: quantize (g + err), return the dequantized
    tensor to feed the collective and the new residual."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    new_err = target - deq
    return deq.astype(g.dtype), new_err


def init_error_state(params):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def tree_compress_decompress(grads, err_state):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def payload_bytes(params) -> int:
    """Bytes a compressed gradient all-reduce would move (int8 + scale)."""
    return sum(int(x.size) + 4 for x in jax.tree.leaves(params))
