"""AdamW with warmup+cosine schedule and global-norm clipping.

Self-contained (no optax in this environment).  The optimizer state mirrors
the parameter sharding (ZeRO-style: sharded moments for free under pjit —
the state inherits each param's NamedSharding).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
