"""Logical-axis sharding rules (MaxText-style) mapping model-declared axis
names to mesh axes, per run mode.

Model code annotates every parameter dimension with a logical name
(``repro.models.*`` init functions return an ``axes`` tree).  This module
turns those annotations into ``NamedSharding`` trees for pjit, with:

* per-mode rule tables (train = FSDP×TP, serve = TP, + pure-DP across pods),
* arch-aware MoE rule (experts ≥ |model| → expert parallelism; otherwise
  TP inside each expert's FFN),
* conflict sanitation (a mesh axis may appear at most once per spec; later
  occurrences are dropped deterministically),
* divisibility checks (a dim only shards if the mesh axis divides it).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

MeshAxes = tuple[str, ...] | str | None


# ---------------------------------------------------------------------------
# jax version compatibility (pinned jax 0.4.37 predates jax.sharding.AxisType
# and the explicit-axis make_mesh/AbstractMesh signatures)
# ---------------------------------------------------------------------------

#: True when this jax exposes the explicit/auto axis-type API (jax >= 0.5).
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """``jax.make_mesh`` with every axis pinned Auto where the API exists,
    and a guarded fallback for older jax (0.4.x has no ``axis_types``)."""
    kwargs = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPES:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
                **kwargs)
        except TypeError:  # AxisType present but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def abstract_mesh(axis_shapes, axis_names) -> jax.sharding.AbstractMesh:
    """AbstractMesh across the signature change: (sizes, names) on jax >= 0.5
    vs a single ((name, size), ...) tuple on 0.4.x."""
    if HAS_AXIS_TYPES:
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def _mesh_size(mesh, name: str) -> int:
    return dict(mesh.shape)[name]


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis name -> mesh axes (None = replicated)."""

    table: Mapping[str, MeshAxes]

    def get(self, logical: str) -> MeshAxes:
        return self.table.get(logical)


def train_rules(cfg: ModelConfig, mesh: Mesh) -> Rules:
    """FSDP(data) × TP(model); the pod axis stays pure-DP (gradients cross
    pods once per step — the slow-link-friendly choice; see DESIGN.md §5)."""
    model_n = _mesh_size(mesh, "model")
    ep = cfg.n_experts >= model_n  # expert parallelism vs TP-in-expert
    table = {
        # embeddings: vocab on model, d_model FSDP on data
        "vocab": "model",
        "embed": "data",
        # attention: heads on model (TP)
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        # dense mlp: ff on model
        "mlp": "model",
        # MoE
        "experts": "model" if ep else None,
        "expert_mlp": None if ep else "model",
        "experts_r": None,
        # mamba
        "ssm_proj": "model",
        "ssm_conv_ch": "model",
        "ssm_inner": "model",
        "ssm_heads": None,
        "conv_k": None,
        # stacking axes
        "layers": None,
        "periods": None,
    }
    return Rules(table)


def serve_rules(cfg: ModelConfig, mesh: Mesh) -> Rules:
    """Pure TP for weights (replicated over data/pod); KV caches shard batch
    on data and sequence-blocks on model (flash-decoding style SP)."""
    model_n = _mesh_size(mesh, "model")
    ep = cfg.n_experts >= model_n
    table = {
        "vocab": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "mlp": "model",
        "experts": "model" if ep else None,
        "expert_mlp": None if ep else "model",
        "experts_r": None,
        "ssm_proj": "model",
        "ssm_conv_ch": "model",
        "ssm_inner": "model",
        "ssm_heads": None,
        "conv_k": None,
        "layers": None,
        "periods": None,
    }
    return Rules(table)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The batch-parallel mesh axes: ("pod","data") on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------


def spec_for_axes(axes: tuple[str, ...], shape: tuple[int, ...],
                  rules: Rules, mesh: Mesh) -> P:
    """Build a sanitized PartitionSpec for one array."""
    used: set[str] = set()
    entries: list[MeshAxes] = []
    for dim, logical in enumerate(axes):
        target = rules.get(logical)
        if target is None:
            entries.append(None)
            continue
        target_t = (target,) if isinstance(target, str) else tuple(target)
        # drop axes already used or not dividing the dim
        kept = []
        size = 1
        for a in target_t:
            n = _mesh_size(mesh, a)
            if a in used:
                continue
            if shape[dim] % (size * n) != 0:
                continue
            kept.append(a)
            size *= n
        for a in kept:
            used.add(a)
        # Singleton axes unwrap to the bare name: PartitionSpec("x") and
        # PartitionSpec(("x",)) mean the same sharding, but only compare
        # equal on newer jax — normalize for the pinned 0.4.37.
        entries.append(kept[0] if len(kept) == 1 else tuple(kept) if kept else None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def make_param_shardings(axes_tree, shapes_tree, rules: Rules, mesh: Mesh):
    """axes_tree mirrors the params tree with logical-axis tuples as leaves;
    shapes_tree provides the corresponding shapes (ShapeDtypeStruct tree)."""

    def one(axes, arr):
        spec = spec_for_axes(axes, arr.shape, rules, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, str) for e in x))


def batch_sharding(mesh: Mesh, sds) -> NamedSharding:
    """[B, ...] inputs: batch over ("pod","data"), honoring divisibility
    (batch=1 long-context shapes stay replicated)."""
    da = data_axes(mesh)
    n = int(np.prod([_mesh_size(mesh, a) for a in da])) if da else 1
    shape = sds.shape if hasattr(sds, "shape") else ()
    if not shape or shape[0] % n != 0:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(da, *([None] * (len(shape) - 1))))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Ambient activation constraints (set by the step builders at trace time)
# ---------------------------------------------------------------------------

_AMBIENT: dict = {"mesh": None}


def set_ambient_mesh(mesh) -> None:
    _AMBIENT["mesh"] = mesh


def constrain(x, *entries):
    """with_sharding_constraint against the ambient mesh; no-op without one.
    Entry "__data__" expands to the mesh's data axes tuple.

    A 1-device mesh (``make_host_mesh`` on a single-device host, or an
    explicit ``--mesh 1,1``) is also a no-op: every constraint it could
    express is full replication, and emitting them would still leave
    sharding-constraint ops in the jaxpr of single-device runs — the
    ambient mesh must leave those runs byte-for-byte untouched."""
    mesh = _AMBIENT["mesh"]
    if mesh is None or isinstance(mesh, jax.sharding.AbstractMesh):
        return x
    if int(np.prod([_mesh_size(mesh, a) for a in mesh.axis_names])) <= 1:
        return x
    da = data_axes(mesh)
    resolved = []
    for e in entries:
        if e == "__data__":
            if not da or x.shape[len(resolved)] % int(
                    np.prod([_mesh_size(mesh, a) for a in da])) != 0:
                resolved.append(None)
            else:
                resolved.append(da)
        elif isinstance(e, str) and e in mesh.axis_names:
            resolved.append(e if x.shape[len(resolved)] % _mesh_size(mesh, e) == 0
                            else None)
        else:
            resolved.append(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# KV-cache / decode-state shardings (serving)
# ---------------------------------------------------------------------------


def cache_shardings(state_shapes, mesh: Mesh):
    """Shard decode state by leaf name (path-aware):

      kv k/v_store + scales : [L, B, Hkv, NB, ...] -> batch→data axes,
                              NB→"model" (sequence parallelism: the paper's
                              compression blocks are the SP sharding unit)
      kv k/v_buf            : [L, B, Hkv, T, D]    -> batch→data
      kv scalars + page_tab : [L] / [L, B, NB]     -> replicated
      ssm "conv"            : [..., B, K, C]       -> batch→data, C→"model"
      ssm "ssm"             : [..., B, H, N, P]    -> batch→data, H→"model"

    Any axis that fails divisibility falls back to replication — which is
    also how paged arenas (store batch extent 1, DESIGN.md §10) degrade
    gracefully: the batch rule can't divide 1, so the shared arena
    replicates while its page axis still shards on "model".
    """
    da = data_axes(mesh)
    da_n = int(np.prod([_mesh_size(mesh, a) for a in da])) if da else 1
    model_n = _mesh_size(mesh, "model")

    store_names = {"k_store", "v_store", "k_min", "k_step", "v_min", "v_step"}
    buf_names = {"k_buf", "v_buf"}

    def one(path, x):
        shp = x.shape
        nd = len(shp)
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        leaf = names[-1] if names else None
        spec = [None] * nd

        def set_if(idx, axes, div):
            if 0 <= idx < nd and shp[idx] % div == 0 and shp[idx] >= div:
                spec[idx] = axes

        if leaf in store_names and nd >= 4:
            set_if(1, da, da_n)
            set_if(3, "model", model_n)  # NB (compression-block) axis
        elif leaf in buf_names and nd >= 4:
            set_if(1, da, da_n)
        elif leaf == "conv" and nd >= 3:
            set_if(nd - 3, da, da_n)
            set_if(nd - 1, "model", model_n)
        elif leaf == "ssm" and nd >= 4:
            set_if(nd - 4, da, da_n)
            set_if(nd - 3, "model", model_n)
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shapes)
