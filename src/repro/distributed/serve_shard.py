"""Mesh-native sharded serving (DESIGN.md §12).

Wraps the per-layer decode attention in an explicit ``shard_map`` over a
``("data", "model")`` mesh so one Server drives every device:

* **"data"** shards the continuous batch: decode slots, page-table rows,
  the raw append buffers' batch axis — and, in paged mode, the shared
  arena's *page axis*.  Data shard ``d`` of ``n_d`` owns global page ids
  ``[d * P_loc, (d+1) * P_loc)`` (``P_loc = pool_pages / n_d``), handed out
  by its own offset ``PagedBlockPool`` — page ids stay globally unique and
  a table entry identifies its owning shard by integer division alone.
  The scheduler allocates a row's pages from the row's own shard, so every
  page a live row references is device-local: no cross-shard softmax
  combine is ever needed, which is what keeps sharded greedy decoding
  **bit-identical** to the single-device run.
* **"model"** shards KV heads *inside attention only*.  Parameters stay
  replicated (a tensor-parallel matmul's ``psum`` would reorder float
  sums and break bit-identity); attention is embarrassingly parallel over
  ``Hkv``, and contiguous ``Hq`` chunks align with their KV groups because
  ``n_model`` must divide ``n_kv_heads``.  The per-head outputs are
  re-gathered (pure data movement) before the output projection.

The machinery registers as the ``"sharded"`` attention backend: the
scheduler pins the *live* decode state's spec to it, ``set_serve_mesh``
supplies the mesh + inner backend at trace time, and the backend dispatches
``shard_map(inner)`` — or falls straight through to the inner backend when
no mesh is set or a shape does not divide (e.g. the batch-1 gathered solo
states the prefix-cache path builds).

Chunked admission (DESIGN.md §13) under a mesh uses the *dense-state*
chunk path: each PREFILLING row chunks through a private replicated
batch-1 state and splices into the sharded arena at the finish, because
encode-to-page through a batch-1 view of the GSPMD-sharded arena would
re-partition page-axis reductions and risk bit drift.  Page reservations
still come from the row's own data shard up front, so chunk pages stay
shard-affine exactly like decode-flushed ones.

CPU testing recipe: export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* python
starts, then build the mesh with ``repro.launch.mesh.make_serve_mesh``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 re-exports at top level; the pinned 0.4.37 does not
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core import cache as kvcache
from repro.core.pool import STORE_FIELDS, PagedBlockPool
from repro.kernels import ops as kernel_ops

Array = jax.Array


# ---------------------------------------------------------------------------
# Mesh bookkeeping
# ---------------------------------------------------------------------------


def mesh_counts(mesh) -> tuple[int, int]:
    """(n_data, n_model) — missing axes count 1."""
    shape = dict(mesh.shape)
    return int(shape.get("data", 1)), int(shape.get("model", 1))


def validate_serve_mesh(mesh, cfg, max_slots: int) -> tuple[int, int]:
    """Check a serving mesh against the model + server shape, with
    actionable errors.  Returns (n_data, n_model)."""
    names = set(mesh.axis_names)
    if names != {"data", "model"}:
        raise ValueError(
            f"serving mesh wants axes ('data', 'model'), got {tuple(mesh.axis_names)}"
            " — build it with repro.launch.mesh.make_serve_mesh")
    n_d, n_m = mesh_counts(mesh)
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"sharded serving supports dense/moe decode state, not "
            f"family={cfg.family!r}")
    n_kv = cfg.n_kv_heads or cfg.n_heads
    if n_kv % n_m:
        raise ValueError(
            f"mesh model axis ({n_m}) must divide n_kv_heads "
            f"({n_kv}); use a ({n_d * n_m},1) mesh for pure data "
            "parallelism")
    if cfg.n_heads % n_m:
        raise ValueError(
            f"mesh model axis ({n_m}) must divide n_heads ({cfg.n_heads})")
    if max_slots % n_d:
        raise ValueError(
            f"mesh data axis ({n_d}) must divide max_slots ({max_slots}): "
            "decode slots shard as contiguous per-shard chunks")
    return n_d, n_m


# ---------------------------------------------------------------------------
# Per-shard page accounting
# ---------------------------------------------------------------------------


class ShardedPagedPool:
    """``n_shards`` offset ``PagedBlockPool``\\ s fronting one global arena.

    Shard ``d`` hands out ids ``[d * per_shard, (d+1) * per_shard)`` —
    the slice of the arena's page axis that lives on data shard ``d`` once
    the arena is sharded ``P(..., "data", ...)``.  ``alloc`` must name its
    shard (the scheduler allocates from the row's shard); ``retain`` /
    ``release`` / ``refcount`` route by page id.  Aggregate accounting
    matches the flat pool's interface so scheduler admission logic and
    ``stats()`` consumers read it unchanged; the invariant
    ``sum(shard free) == free_pages`` is property-tested.
    """

    def __init__(self, n_pages: int, page_nbytes_per_layer, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        if n_pages % n_shards:
            raise ValueError(
                f"n_pages ({n_pages}) must divide over {n_shards} shards")
        self.n_pages = int(n_pages)
        self.per_shard = self.n_pages // n_shards
        self.page_nbytes_per_layer = tuple(int(b) for b in page_nbytes_per_layer)
        self.shards = [
            PagedBlockPool(self.per_shard, self.page_nbytes_per_layer,
                           offset=d * self.per_shard)
            for d in range(n_shards)
        ]

    def shard_of(self, page) -> int:
        return int(page) // self.per_shard

    # -- allocation (routed) -------------------------------------------------
    def alloc(self, n: int, shard: int = 0) -> list[int]:
        return self.shards[shard].alloc(n)

    def retain(self, pages) -> None:
        for p in pages:
            self.shards[self.shard_of(p)].retain([p])

    def release(self, pages) -> list[int]:
        freed: list[int] = []
        for p in pages:
            freed.extend(self.shards[self.shard_of(p)].release([p]))
        return freed

    def refcount(self, page) -> int:
        return self.shards[self.shard_of(page)].refcount(page)

    # -- aggregate accounting (flat-pool interface) --------------------------
    @property
    def free_pages(self) -> int:
        return sum(s.free_pages for s in self.shards)

    @property
    def live_pages(self) -> int:
        return sum(s.live_pages for s in self.shards)

    @property
    def high_water(self) -> int:
        return sum(s.high_water for s in self.shards)

    @property
    def bytes_per_page(self) -> int:
        return sum(self.page_nbytes_per_layer)

    @property
    def live_bytes(self) -> int:
        return self.live_pages * self.bytes_per_page

    @property
    def total_bytes(self) -> int:
        return self.n_pages * self.bytes_per_page

    def stats(self) -> dict:
        per = [s.stats() for s in self.shards]
        agg = {k: sum(p[k] for p in per) for k in per[0]
               if k not in ("bytes_per_page", "bytes_live_by_layer")}
        agg["bytes_per_page"] = self.bytes_per_page
        agg["bytes_live_by_layer"] = [
            sum(xs) for xs in zip(*(p["bytes_live_by_layer"] for p in per))]
        return agg

    def shard_stats(self) -> list[dict]:
        return [s.stats() for s in self.shards]


# ---------------------------------------------------------------------------
# Decode-state partition specs / shardings
# ---------------------------------------------------------------------------


def _is_cache(x) -> bool:
    return isinstance(x, kvcache.LayerKVCache)


def _cache_field_spec(name: str, arr, spec, lead: int,
                      n_d: int, n_m: int) -> P:
    """PartitionSpec for one LayerKVCache leaf under the serving mesh.

    Defensive by construction: an axis only shards when its extent matches
    the expected role AND the mesh axis divides it — anything else stays
    replicated, so odd shapes degrade instead of erroring inside pjit.
    """
    shp, nd = arr.shape, arr.ndim
    ent: list = [None] * nd

    def heads_ok(ax: int) -> bool:
        return ax < nd and shp[ax] > 0 and shp[ax] % n_m == 0

    if name in STORE_FIELDS:
        if nd - lead < 4:  # layout dummy scales (e.g. raw) stay replicated
            return P()
        if heads_ok(lead + 1):
            ent[lead + 1] = "model"
        if spec.paged:
            # shared arena: batch extent 1, pages shard over "data"
            if shp[lead + 2] == spec.pool_pages and spec.pool_pages % n_d == 0:
                ent[lead + 2] = "data"
        elif shp[lead] % n_d == 0:
            ent[lead] = "data"
        return P(*ent)
    if name in ("k_buf", "v_buf"):
        if shp[lead] % n_d == 0:
            ent[lead] = "data"
        if heads_ok(lead + 1):
            ent[lead + 1] = "model"
        return P(*ent)
    if name in ("n_flushed", "buf_len"):
        if shp[lead] % n_d == 0:
            ent[lead] = "data"
        return P(*ent)
    if name == "page_tab":
        if spec.paged and nd - lead == 2 and shp[lead] % n_d == 0:
            ent[lead] = "data"
            return P(*ent)
        return P()
    return P()


def cache_partition_specs(c: kvcache.LayerKVCache, mesh) -> kvcache.LayerKVCache:
    """LayerKVCache-shaped pytree of PartitionSpecs (stacked caches get a
    replicated leading layer axis automatically via ``lead``)."""
    n_d, n_m = mesh_counts(mesh)
    lead = c.n_flushed.ndim - 1
    specs = {f: _cache_field_spec(f, getattr(c, f), c.spec, lead, n_d, n_m)
             for f in c._FIELDS}
    return type(c)(**specs, spec=c.spec)


def decode_state_shardings(state, mesh):
    """Canonical ``NamedSharding`` tree for a Server's live decode state.

    The Server ``device_put``\\ s the freshly-initialized state against this
    tree and constrains every state-producing closure's output to it, so
    array placement is stable across steps (no resharding thrash, donation
    stays buffer-compatible).
    """

    def one(x):
        if _is_cache(x):
            specs = cache_partition_specs(x, mesh)
            return type(x)(
                **{f: NamedSharding(mesh, getattr(specs, f)) for f in x._FIELDS},
                spec=x.spec)
        return NamedSharding(mesh, P())

    return jax.tree.map(one, state, is_leaf=_is_cache)


def constrain_state(state, shardings):
    """``with_sharding_constraint`` a state tree leaf-by-leaf (inside jit)."""
    return jax.tree.map(jax.lax.with_sharding_constraint, state, shardings)


def override_backend(state, backend: str):
    """Rewrite every cache's ``attn_backend`` (specs are static aux data —
    e.g. ``pool.gather_pages`` keeps the live state's ``"sharded"`` pin on
    the batch-1 dense seed it builds, where the solo chunked-prefill
    closures need the inner backend)."""

    def one(c):
        if _is_cache(c):
            return c.with_spec(dataclasses.replace(c.spec, attn_backend=backend))
        return c

    return jax.tree.map(one, state, is_leaf=_is_cache)


# ---------------------------------------------------------------------------
# The "sharded" attention backend
# ---------------------------------------------------------------------------

# Trace-time context for the backend below.  The Server sets it in __init__
# (before tracing its closures) and re-asserts it at the top of step();
# per-server jit closures capture whatever was current when they traced.
_CTX: dict = {"mesh": None, "inner": "auto"}


def set_serve_mesh(mesh, inner: str = "auto") -> None:
    """Bind the serving mesh + inner backend the ``"sharded"`` backend
    wraps.  ``mesh=None`` makes it a pass-through to ``inner``."""
    _CTX["mesh"] = mesh
    _CTX["inner"] = inner or "auto"


def _resolve_inner(layout) -> str:
    inner = kernel_ops.resolve_backend(_CTX["inner"], layout)
    if inner == "sharded":  # self-nesting (e.g. REPRO_ATTN_BACKEND=sharded)
        inner = "xla"
    return inner


@kernel_ops.register_backend("sharded")
def _sharded_backend(cache, q: Array, scale: float | None = None) -> Array:
    """shard_map the inner decode-attention backend over (data, model).

    Falls through to the inner backend directly when no mesh is bound or a
    shape does not divide the mesh — notably the batch-1 gathered solo
    states of the prefix-cache admission path, which inherit the live
    spec's ``"sharded"`` pin but run on replicated arrays.
    """
    mesh = _CTX["mesh"]
    spec = cache.spec
    inner = _resolve_inner(spec.impl)
    if mesh is None:
        return kernel_ops._BACKENDS[inner](cache, q, scale)
    n_d, n_m = mesh_counts(mesh)
    B, Hq, _ = q.shape
    Hkv = cache.k_buf.shape[1]
    if (B % n_d or Hkv % n_m or Hq % n_m
            or (spec.paged and spec.pool_pages % n_d)):
        return kernel_ops._BACKENDS[inner](cache, q, scale)

    p_loc = spec.pool_pages // n_d if spec.paged else 0

    def body(c, ql):
        lspec = dataclasses.replace(spec, attn_backend=inner)
        if spec.paged:
            # Each shard holds pages [base, base + p_loc) of the arena;
            # translate the (global-id) table to local ids and mark blocks
            # hosted elsewhere unassigned — the attention paths' validity
            # guards make those contribute nothing.  Scheduler invariant:
            # a row's pages all come from the row's own data shard, so the
            # rows this shard computes never lose a live block.
            base = jax.lax.axis_index("data") * p_loc
            pt = c.page_tab
            ptl = jnp.where((pt >= base) & (pt < base + p_loc), pt - base, -1)
            lspec = dataclasses.replace(lspec, pool_pages=p_loc)
            c = dataclasses.replace(c, page_tab=ptl, spec=lspec)
        else:
            c = c.with_spec(lspec)
        return kernel_ops._BACKENDS[inner](c, ql, scale)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(cache_partition_specs(cache, mesh), P("data", "model", None)),
        out_specs=P("data", "model", None),
        check_rep=False)
    o = fn(cache, q)
    # Pure all-gather of the head axis before o_proj: replicated weights +
    # per-head-exact attention keep greedy outputs bit-identical.
    return jax.lax.with_sharding_constraint(
        o, NamedSharding(mesh, P("data", None, None)))
