"""Public KVComp facade — the one import for compressing, serving, and
sizing KV caches.

    from repro import api
    from repro.core.policy import CompressionPolicy, TensorPolicy, LayerOverride

    policy = CompressionPolicy(layout="packed")      # or kivi / huffman / raw
    cache  = api.compress(k, v, policy=policy)       # Store (prefill bulk)
    out    = api.attend(cache, q)                    # Fetch (fused algebra)
    k2, v2 = api.decompress(cache)                   # reconstruct
    report = api.estimate_ratio(k, v, policy=policy) # exact size accounting

    server = api.serve(cfg, params, max_slots=8)     # continuous batching
    handle = server.submit(api.Request(prompt, max_new_tokens=64))
    for tok in handle.tokens(): ...                  # streaming
    result = handle.result()                         # or block for the rest

Everything dispatches through the ``CacheLayout`` registry
(``repro.core.layouts``): any layout registered with
``@register_layout(name)`` — including the four built-ins raw / packed /
kivi / huffman — is servable through this module with no other code aware
of it.  Examples and benchmarks consume this facade rather than reaching
into the internals.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import obs  # noqa: F401  (observability facade: DESIGN.md §14)
from repro.core import cache as kvcache
from repro.core import huffman, layouts, quant
from repro.core.policy import CompressionPolicy, LayerOverride, TensorPolicy  # noqa: F401
from repro.kernels import ops as kernel_ops
from repro.serve.scheduler import Handle, Request, Server, ServerConfig  # noqa: F401

__all__ = [
    "CompressionPolicy", "TensorPolicy", "LayerOverride",
    "available_layouts", "register_layout", "make_spec", "make_cache",
    "available_backends", "register_backend",
    "compress", "decompress", "append", "attend", "estimate_ratio",
    "serve", "Server", "ServerConfig", "Request", "Handle", "obs",
]

register_layout = layouts.register_layout
register_backend = kernel_ops.register_backend


def serve(cfg, params, *, max_slots: int = 8, max_seq: int = 4096,
          pad_id: int = 0, policy: str = "fcfs",
          attn_backend: str | None = None,
          cache_mode: str | None = None,
          pool_hbm_bytes: int | None = None,
          prefix_cache: str = "off",
          mesh=None,
          prefill_mode: str = "chunked",
          prefill_chunk_tokens: int | None = None,
          trace: str = "off",
          max_requeues: int = 32,
          max_pending: int | None = None,
          backpressure: str = "reject",
          default_deadline_s: float | None = None,
          faults=None,
          audit_every: int = 0,
          q_chunk: int = 512, kv_chunk: int = 512) -> Server:
    """Launch a continuous-batching server over ``cfg``'s cache policy.

    Returns a ``repro.serve.scheduler.Server``: ``submit(Request) -> Handle``
    with ``handle.result()`` / streaming ``handle.tokens()``; requests join
    and leave decode slots mid-flight at their own per-row positions.
    ``policy`` picks the admission order ("fcfs" or "ljf"; DESIGN.md §8).
    ``attn_backend`` overrides the decode-attention backend (DESIGN.md §9;
    None keeps ``cfg.attn_backend`` — "auto" runs the fused
    in-situ-decompression kernel on TPU, the blockwise scan elsewhere).
    ``cache_mode`` overrides ``cfg.cache_mode`` (DESIGN.md §10): "paged"
    pools compressed blocks in shared per-layer arenas sized by
    ``pool_hbm_bytes`` and admits by memory pressure — slots oversubscribe
    the dense reservation by the compression ratio, preempting + requeueing
    the youngest request if the pool runs dry (tokens are unaffected);
    ``server.stats()`` reports live pool occupancy.
    ``prefix_cache="on"`` (paged mode only; DESIGN.md §11) shares
    block-aligned prompt prefixes across requests through a radix index
    over refcounted compressed pages — admission splices cached page ids
    and prefills only the divergent suffix, preempted requests resume from
    cached pages, and ``server.stats()["prefix"]`` reports hit-rate /
    reuse / copy-on-write counters ("noshare" runs the same chunked
    admission path without sharing — the accounting baseline).
    ``mesh`` (DESIGN.md §12) serves across devices: a jax Mesh with
    ("data", "model") axes — ``repro.launch.mesh.make_serve_mesh("dp,tp")``
    builds one — shards decode slots, page tables, and the paged arena's
    page axis over "data" and KV heads over "model", with parameters
    replicated so greedy outputs stay bit-identical to the single-device
    server; ``server.stats()["shards"]`` reports per-shard page pressure.
    ``prefill_mode`` (DESIGN.md §13) picks the admission style: "chunked"
    (the default) splits every prompt into block-multiple chunks spliced
    between decode steps — at most ``prefill_chunk_tokens`` prompt tokens
    (default ``8 * block_size``; must be a positive multiple of the cache
    block size) ride alongside the live decode batch per step, so a long
    prompt no longer stalls in-flight streams, and in paged mode each
    chunk's KV encodes straight into pooled pages (peak admission memory
    O(chunk), not O(prompt)); "solo" restores the blocking full-length
    prefill. Greedy outputs are bit-identical either way;
    ``server.stats()["prefill"]`` reports chunks in flight and tokens
    co-scheduled with decode.
    ``trace`` (DESIGN.md §14) turns on the ring-buffered scheduler event
    trace ("events" records every scheduling decision, "full" adds decode
    dispatch spans); ``server.trace.write_chrome(path)`` — or
    ``server.shutdown(trace_out=...)`` — exports it as Perfetto-loadable
    Chrome trace-event JSON, and ``server.metrics`` is the typed registry
    behind ``server.stats()``.
    Request-lifecycle hardening (DESIGN.md §15): failures are isolated —
    pool exhaustion with nothing reclaimable requeues the affected request
    up to ``max_requeues`` times (the same budget caps preemption storms,
    with the oldest request always protected) and then fails ONLY that
    request (``Result.finish_reason == "error"`` with ``Result.error``
    naming the cause; other streams are bit-identical to an undisturbed
    run).  ``Handle.cancel()`` and ``Request.deadline_s`` /
    ``default_deadline_s`` retire requests in any state ("cancelled" /
    "deadline"); ``max_pending`` bounds the admission queue, with
    ``backpressure`` picking "reject" (submit raises ``QueueFull``) or
    "block" (submit drives the server until the queue drains).
    ``faults`` takes a ``repro.serve.faults.FaultPlan`` for deterministic
    seeded fault injection at the named scheduler sites, and
    ``audit_every=N`` cross-checks the server's pool/page-table/index
    bookkeeping every N steps (``repro.serve.faults.InvariantAuditor``),
    raising on the first violation.
    """
    return Server(cfg, params,
                  ServerConfig(max_slots=max_slots, max_seq=max_seq,
                               pad_id=pad_id, policy=policy,
                               attn_backend=attn_backend,
                               cache_mode=cache_mode,
                               pool_hbm_bytes=pool_hbm_bytes,
                               prefix_cache=prefix_cache,
                               mesh=mesh,
                               prefill_mode=prefill_mode,
                               prefill_chunk_tokens=prefill_chunk_tokens,
                               trace=trace,
                               max_requeues=max_requeues,
                               max_pending=max_pending,
                               backpressure=backpressure,
                               default_deadline_s=default_deadline_s,
                               faults=faults,
                               audit_every=audit_every),
                  q_chunk=q_chunk, kv_chunk=kv_chunk)


def available_layouts() -> tuple[str, ...]:
    """Names of every registered cache layout."""
    return layouts.available_layouts()


def available_backends() -> tuple[str, ...]:
    """Names of every registered decode-attention backend."""
    return kernel_ops.available_backends()


def _policy(policy: CompressionPolicy | None) -> CompressionPolicy:
    return policy if policy is not None else CompressionPolicy()


def make_spec(policy: CompressionPolicy | None = None, *, layer: int = 0,
              max_seq: int = 4096, window: int | None = None) -> kvcache.CacheSpec:
    """Resolve a (possibly per-layer-overridden) policy to one CacheSpec."""
    return _policy(policy).spec_for_layer(layer, max_seq=max_seq, window=window)


def make_cache(batch: int, n_kv_heads: int, head_dim: int, *,
               policy: CompressionPolicy | None = None, layer: int = 0,
               max_seq: int = 4096, window: int | None = None,
               dtype=jnp.bfloat16) -> kvcache.LayerKVCache:
    """An empty, servable layer cache under the policy's layout."""
    spec = make_spec(policy, layer=layer, max_seq=max_seq, window=window)
    return kvcache.init_layer_cache(spec, batch, n_kv_heads, head_dim, dtype)


def compress(k, v, *, policy: CompressionPolicy | None = None, layer: int = 0,
             max_seq: int | None = None, window: int | None = None,
             dtype=jnp.bfloat16) -> kvcache.LayerKVCache:
    """Bulk-compress prompt KV [B, Hkv, S, D] into a layer cache (Store)."""
    S = k.shape[2]
    spec = make_spec(policy, layer=layer,
                     max_seq=max_seq if max_seq is not None else S,
                     window=window)
    return kvcache.prefill(spec, k, v, dtype)


def decompress(cache: kvcache.LayerKVCache):
    """Reconstruct (k, v) [B, Hkv, S, D] from a cache — decoded store blocks
    followed by the exact raw-buffer tail.  Host-side convenience: the cache
    lengths must be concrete (outside jit).  Paged caches are first gathered
    back into a private dense ring (``repro.core.pool.to_dense``)."""
    from repro.core import pool as blockpool

    cache = blockpool.to_dense(cache)
    spec = cache.spec
    k_deq, v_deq = spec.impl.fetch(spec, cache)
    B, H, NB, T, D = k_deq.shape
    nf = np.asarray(cache.n_flushed)
    bl = np.asarray(cache.buf_len)
    if not ((nf == nf[0]).all() and (bl == bl[0]).all()):
        raise ValueError(
            "decompress needs uniform per-row lengths (rows of a continuous "
            f"batch are at different positions: n_flushed={nf.tolist()}, "
            f"buf_len={bl.tolist()}); decompress rows individually instead")
    nb = int(nf[0])
    if nb > NB:
        raise ValueError("cache has evicted blocks; only the last "
                         f"{NB * T} store tokens are reconstructible")
    buf = int(bl[0])
    k = jnp.concatenate(
        [k_deq.reshape(B, H, NB * T, D)[:, :, : nb * T], cache.k_buf[:, :, :buf]],
        axis=2)
    v = jnp.concatenate(
        [v_deq.reshape(B, H, NB * T, D)[:, :, : nb * T], cache.v_buf[:, :, :buf]],
        axis=2)
    return k, v


def append(cache: kvcache.LayerKVCache, k_new, v_new) -> kvcache.LayerKVCache:
    """Append one token's KV [B, Hkv, D] (compress-on-block-overflow)."""
    return kvcache.append(cache, k_new, v_new)


def attend(cache: kvcache.LayerKVCache, q, scale: float | None = None,
           backend: str | None = None):
    """Single-token decode attention over (store ∥ buffer) -> [B, Hq, D].

    Dispatches through the attention-backend registry; ``backend=None``
    defers to the cache spec (``"auto"``: fused Pallas kernel on TPU for
    fused-capable layouts, blockwise lazily-dequantized scan elsewhere).
    """
    return kvcache.attend(cache, q, scale, backend=backend)


def estimate_ratio(k=None, v=None, *, policy: CompressionPolicy | None = None,
                   layer: int = 0, which: str = "both") -> dict:
    """Exact compression-ratio accounting of this policy on real tensors.

    Quantizes K (BlockQuant) and/or V (TokenQuant) under the resolved layer
    policy, fits Huffman codebooks where the layout needs them, and returns
    per-tensor ``RatioReport``s plus the combined ratio — the collapse of
    the old ``KVCompCodec.report_k``/``report_v`` duplication into the
    layout objects.  ``which`` ∈ {"k", "v", "both"} limits the work when a
    caller sweeps only one tensor.
    """
    if which not in ("k", "v", "both"):
        raise ValueError(f"which must be k|v|both, got {which!r}")
    if (which in ("k", "both") and k is None) or \
            (which in ("v", "both") and v is None):
        raise ValueError(f"which={which!r} needs the corresponding tensor(s)")
    ref = k if k is not None else v
    spec = make_spec(policy, layer=layer, max_seq=int(ref.shape[0]))
    lay = spec.impl
    head_dim = int(ref.shape[-1])

    def report(q):
        book = None
        if lay.needs_codebook:
            book = huffman.build_codebook(np.asarray(huffman.histogram(q.codes)))
        return lay.size_report(q, block_size=spec.block_size, head_dim=head_dim,
                               kivi_bits=spec.bits_k, book=book)

    out = {"layout": spec.layout}
    if which in ("k", "both"):
        qk = (quant.kivi_quantize_k(k, spec.bits_k, 32) if lay.kivi_step
              else quant.quantize_k_block(k, spec.rel_scale_k, spec.block_size))
        out["k"] = report(qk)
    if which in ("v", "both"):
        qv = (quant.kivi_quantize_v(v, spec.bits_v) if lay.kivi_step
              else quant.quantize_v_token(v, spec.rel_scale_v))
        out["v"] = report(qv)
    reports = [out[t] for t in ("k", "v") if t in out]
    total_bits = sum(r.total_bits for r in reports)
    n = sum(r.n_values for r in reports)
    out["ratio"] = n * layouts.RAW_BITS_PER_VALUE / max(total_bits, 1)
    return out
