"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    ckpt_dir/
      step_000120.tmp/        # written first
        manifest.msgpack      # tree structure, shapes, dtypes, step, meta
        arrays/<leaf-id>.bin  # raw little-endian bytes per leaf
      step_000120/            # atomic rename after fsync — commit marker

Fault-tolerance properties:
  * a crash mid-write leaves only a ``.tmp`` dir (ignored on restore);
  * ``restore`` resharding: arrays are loaded host-side and ``device_put``
    against the *current* mesh's shardings, so a job restarted on a
    different device count resumes seamlessly (elastic restart);
  * ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next steps.
"""

from __future__ import annotations

import dataclasses
import re
import shutil
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)$")


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _np_view(x: np.ndarray) -> tuple[np.ndarray, str]:
    """bfloat16-safe byte view (ml_dtypes arrays round-trip via uint16)."""
    dt = str(x.dtype)
    if dt == "bfloat16":
        return x.view(np.uint16), "bfloat16"
    return x, dt


def save(ckpt_dir: str | Path, step: int, tree, meta: dict | None = None) -> Path:
    """Synchronous sharded save with atomic commit."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step:06d}.tmp"
    final = ckpt_dir / f"step_{step:06d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        host = np.asarray(jax.device_get(leaf))
        view, dt = _np_view(host)
        fname = f"{i:05d}.bin"
        (tmp / "arrays" / fname).write_bytes(view.tobytes())
        manifest["leaves"].append({
            "path": _path_str(path), "file": fname,
            "shape": list(host.shape), "dtype": dt,
        })
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := _STEP_RE.search(p.name)) and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, tree_like, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like``; reshard onto
    ``shardings`` (a matching tree of NamedSharding) if given — this is the
    elastic-restart path."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:06d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes())

    leaves, treedef = _leaf_paths(tree_like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _leaf_paths(shardings)[0]]

    out = []
    for i, (path, leaf) in enumerate(leaves):
        key = _path_str(path)
        ent = by_path[key]
        raw = (d / "arrays" / ent["file"]).read_bytes()
        if ent["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(ent["shape"])
            arr = arr.view(jnp.bfloat16.dtype)
        else:
            arr = np.frombuffer(raw, np.dtype(ent["dtype"])).reshape(ent["shape"])
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree_like), out), manifest


def gc_old(ckpt_dir: str | Path, keep: int = 3) -> None:
    """Keep the newest ``keep`` committed checkpoints; drop stale .tmp dirs."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    committed = sorted(
        (p for p in ckpt_dir.iterdir() if _STEP_RE.search(p.name)
         and not p.name.endswith(".tmp")),
        key=lambda p: int(_STEP_RE.search(p.name).group(1)))
    for p in committed[:-keep] if keep else committed:
        shutil.rmtree(p)
    for p in ckpt_dir.iterdir():
        if p.name.endswith(".tmp"):
            shutil.rmtree(p)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a daemon thread."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, step: int, tree, meta: dict | None = None):
        self.wait()  # one in-flight write at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, meta)
                gc_old(self.ckpt_dir, self.keep)
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
