"""Typed serving metrics: counters, gauges, fixed-bucket histograms, and the
registry that names them (DESIGN.md §14).

The serving layer used to keep its telemetry as hand-rolled ints scattered
over four modules (``Server._pf`` / ``_pfx`` / ``preemptions``,
``PagedBlockPool.high_water``, ``PrefixIndex.inserted_blocks``, the sharded
pool's per-shard copies), each surfaced through a differently shaped
``stats()`` dict.  This module is the one vocabulary they all route through:

* ``Counter`` — monotone event count (``inc``).
* ``Gauge``   — last-written level (``set``) with a ``set_max`` hook for
  high-water marks.
* ``Histogram`` — fixed-bucket distribution for latencies.  The bucket
  edges are chosen at construction and the hot path is allocation-free:
  ``observe`` is one ``bisect`` into a static edge list plus two scalar
  adds — no per-sample storage, so a million-token serve run costs the
  same memory as an idle one.  Quantiles come from the cumulative bucket
  counts with linear interpolation inside the winning bucket (the standard
  Prometheus ``histogram_quantile`` estimate).
* ``MetricsRegistry`` — dotted-name -> metric map with factory helpers, a
  nested-dict ``snapshot()`` (the JSON exporter and the substrate of
  ``Server.stats()``), and a ``prometheus_text()`` exposition dump.

Metric objects are standalone (the pool and prefix index create their own
without a registry); ``MetricsRegistry.register`` adopts an existing object
under a name, so one registry can present every component's metrics in a
single tree.
"""

from __future__ import annotations

from bisect import bisect_right

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LATENCY_BUCKETS_S"]

# Default latency edges: log-spaced 100us .. ~2min, the span between one
# cached decode dispatch on accelerator and a cold multi-minute prefill on
# the CPU CI leg.  22 finite buckets + overflow keeps quantile resolution
# ~1.8x per step while the per-observe cost stays a short bisect.
LATENCY_BUCKETS_S = tuple(1e-4 * (1.9 ** i) for i in range(22))


class Counter:
    """Monotonically increasing event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written level; ``set_max`` keeps a high-water mark."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def set_max(self, v) -> None:
        if v > self.value:
            self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket distribution; allocation-free ``observe``.

    ``edges`` are the finite upper bounds; ``counts`` has one extra slot
    for the overflow (+inf) bucket.  ``quantile`` interpolates linearly
    inside the bucket that crosses the target rank — exact at the recorded
    resolution, never allocating or sorting samples.
    """

    __slots__ = ("edges", "counts", "count", "sum", "min", "max")

    def __init__(self, edges=LATENCY_BUCKETS_S):
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError("histogram edges must be strictly increasing")
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.counts[bisect_right(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0..1) from the bucket counts; 0.0 when
        empty.  The min/max trackers clamp the interpolation so a p99 can
        never exceed the largest value actually observed."""
        if not self.count:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= rank:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else self.max
                lo = max(lo, self.min) if i == 0 or seen == 0 else lo
                frac = (rank - seen) / c
                v = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                return min(max(v, self.min), self.max)
            seen += c
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Dotted-name -> metric map: the single tree ``Server.stats()``,
    the JSON snapshot, and the Prometheus dump are all views over."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    # -- factories / adoption -------------------------------------------------
    def register(self, name: str, metric):
        """Adopt an existing metric object (a component built standalone,
        e.g. the pool's high-water gauge) under ``name``.  Re-registering a
        name replaces the binding — a Server rebuilt over the same pool
        keeps one entry."""
        self._metrics[str(name)] = metric
        return metric

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, edges=LATENCY_BUCKETS_S) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self.register(name, Histogram(edges))
        if not isinstance(m, Histogram):
            raise TypeError(f"{name!r} is registered as {type(m).__name__}")
        return m

    def _get(self, name, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self.register(name, cls())
        if not isinstance(m, cls):
            raise TypeError(f"{name!r} is registered as {type(m).__name__}")
        return m

    # -- views ----------------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """Nested dict keyed by the dotted-name segments: counters/gauges
        become leaves, histograms become their summary dicts."""
        out: dict = {}
        for name in sorted(self._metrics):
            node = out
            parts = name.split(".")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = self._metrics[name].snapshot()
        return out

    def prometheus_text(self, prefix: str = "kvcomp") -> str:
        """Prometheus text exposition of every registered metric.  Dotted
        names flatten to underscores; histograms emit the standard
        ``_bucket``/``_sum``/``_count`` cumulative series."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            flat = f"{prefix}_{name.replace('.', '_').replace('-', '_')}"
            if isinstance(m, Counter):
                lines.append(f"# TYPE {flat} counter")
                lines.append(f"{flat} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {flat} gauge")
                lines.append(f"{flat} {m.value}")
            elif isinstance(m, Histogram):
                lines.append(f"# TYPE {flat} histogram")
                cum = 0
                for edge, c in zip(m.edges, m.counts):
                    cum += c
                    lines.append(f'{flat}_bucket{{le="{edge:g}"}} {cum}')
                lines.append(f'{flat}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{flat}_sum {m.sum}")
                lines.append(f"{flat}_count {m.count}")
        return "\n".join(lines) + "\n"
