"""Unified serving observability (DESIGN.md §14): metrics registry,
structured scheduler event trace, and kernel profiling hooks.

    from repro import obs

    server = api.serve(cfg, params, trace="events")
    ...; server.run()
    print(obs.format_snapshot(server.stats()))   # the one stats printer
    server.shutdown(metrics_out="metrics.json",  # JSON + .prom exposition
                    trace_out="trace.json")      # Perfetto-loadable

Three pillars, one import:

* ``obs.metrics`` — ``Counter`` / ``Gauge`` / ``Histogram`` /
  ``MetricsRegistry``: every serving counter (scheduler, pool, prefix
  index, sharded pools) routes through one registry whose ``snapshot()``
  is the documented ``Server.stats()`` tree.
* ``obs.trace`` — ``EventTrace``: ring-buffered scheduler decisions
  (``ServerConfig.trace=off|events|full``) exportable as Chrome
  trace-event JSON with per-request tracks.
* ``obs.profiling`` — ``annotate`` / ``annotation`` / ``trace_capture``:
  named scopes on the compression kernels and opt-in ``jax.profiler``
  capture.
"""

from __future__ import annotations

from repro.obs.metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, LATENCY_BUCKETS_S,
)
from repro.obs.profiling import annotate, annotation, trace_capture  # noqa: F401
from repro.obs.trace import EVENT_KINDS, TRACE_LEVELS, Event, EventTrace  # noqa: F401

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "LATENCY_BUCKETS_S",
    "EventTrace", "Event", "EVENT_KINDS", "TRACE_LEVELS",
    "annotate", "annotation", "trace_capture",
    "format_snapshot", "bench_columns", "BENCH_COLUMNS",
]


def format_snapshot(stats: dict) -> str:
    """Render one ``Server.stats()`` tree as the human-readable block the
    launchers print — the single replacement for the hand-rolled printers
    ``launch.serve`` and ``examples/serve_compressed.py`` used to carry
    separately (they drifted; this one reads the documented schema)."""
    lines: list[str] = []
    lines.append(f"  serve[{stats['cache_mode']}]: active={stats['active']} "
                 f"pending={stats['pending']} "
                 f"preemptions={stats['preemptions']}")
    lc = stats.get("lifecycle")
    if lc:
        lines.append(
            f"  lifecycle: submitted={lc['submitted']} "
            f"failures={lc['failures']} cancelled={lc['cancelled']} "
            f"deadline_exceeded={lc['deadline_exceeded']} "
            f"requeues={lc['requeues']} rejected={lc['rejected']}")
    pf = stats["prefill"]
    lines.append(
        f"  prefill[{pf['mode']}]: chunk_tokens={pf['chunk_tokens']} "
        f"tokens={pf['prefill_tokens']} chunks={pf['chunks']} "
        f"coscheduled={pf['coscheduled_tokens']} "
        f"stalled_decode_steps={pf['stalled_decode_steps']} "
        f"preemptions={pf['prefill_preemptions']}")
    lat = stats.get("latency")
    if lat and lat["ttft_s"]["count"]:
        def ms(v):
            return f"{v * 1e3:.0f}ms"
        lines.append(
            f"  latency: ttft p50={ms(lat['ttft_s']['p50'])} "
            f"p99={ms(lat['ttft_s']['p99'])}  "
            f"itl p50={ms(lat['itl_s']['p50'])} "
            f"p99={ms(lat['itl_s']['p99'])}  "
            f"queue p50={ms(lat['queue_wait_s']['p50'])} "
            f"(n={lat['ttft_s']['count']})")
    if "pool" in stats:
        pl = stats["pool"]
        lines.append(
            f"  pool: {pl['pages_total']} pages x {pl['bytes_per_page']}B "
            f"(live {pl['pages_live']}, high water {pl['high_water_pages']}, "
            f"{pl['bytes_total']:,}B total)")
    if "shards" in stats:
        sh = stats["shards"]
        per = " ".join(
            (f"s{i}:{p['pages_live']}L/{p['pages_free']}F"
             f"(hw {p['high_water_pages']}, pre {p['preemptions']})"
             if "pages_live" in p else f"s{i}:(pre {p['preemptions']})")
            for i, p in enumerate(sh["per_shard"]))
        lines.append(f"  shards: data={sh['n_data']} model={sh['n_model']}"
                     f"{' ' + per if per else ''}")
    if "prefix" in stats:
        px = stats["prefix"]
        line = (f"  prefix[{px['mode']}]: hit_rate={px['hit_rate']:.2f} "
                f"({px['hits']}/{px['lookups']} lookups) "
                f"reused_tokens={px['reused_tokens']} "
                f"prefill_tokens={px['prefill_tokens']} "
                f"resumes={px['resumes']} cow_breaks={px['cow_breaks']}")
        if "pool" in stats:
            pl = stats["pool"]
            line += (f" refs_total={pl['refs_total']} "
                     f"pages_shared={pl['pages_shared']}")
        lines.append(line)
    if "trace" in stats:
        tr = stats["trace"]
        lines.append(f"  trace[{tr['level']}]: events={tr['events']} "
                     f"dropped={tr['dropped']}")
    return "\n".join(lines)


# The histogram-derived columns benchmarks/run.py appends to every CSV row
# (sourced from the serving registry, not re-derived per script).
BENCH_COLUMNS = ("ttft_p50_s", "ttft_p99_s", "itl_p50_s", "itl_p99_s",
                 "preemptions", "cow_breaks")


def bench_columns(server) -> dict:
    """The registry-sourced benchmark columns for one Server: TTFT/ITL
    quantiles straight from the latency histograms plus the preemption and
    copy-on-write counters.  Bench scripts embed this under ``"metrics"``
    in their ``BENCH_*.json`` so ``benchmarks/run.py`` (and CI artifact
    consumers) read one schema."""
    reg = server.metrics
    ttft, itl = reg.histogram("serve.ttft_s"), reg.histogram("serve.itl_s")
    cow = reg.get("prefix.cow_breaks")
    return {
        "ttft_p50_s": ttft.quantile(0.50),
        "ttft_p99_s": ttft.quantile(0.99),
        "itl_p50_s": itl.quantile(0.50),
        "itl_p99_s": itl.quantile(0.99),
        "preemptions": int(server.preemptions),
        "cow_breaks": int(cow.value) if cow is not None else 0,
    }
