"""Kernel profiling hooks: named scopes for compression stages and opt-in
``jax.profiler`` capture (DESIGN.md §14).

The Real-TPU ROADMAP item needs device profiles that attribute time to the
*compression* stages KVComp adds — fused in-situ-decompression attention,
the ``pack_encode`` Store path, the huffman LUT decode, the blockwise span
loop — not one undifferentiated jit blob.  ``annotate(name)`` wraps a
region in ``jax.named_scope`` so the XLA ops it traces carry the name into
any profile (TensorBoard, Perfetto, ``xprof``); it is a trace-time-only
construct with zero runtime cost, safe on every hot path.  ``annotation``
is the *runtime* counterpart (``jax.profiler.TraceAnnotation``) for host
regions, and ``trace_capture(dir)`` brackets a block with
``jax.profiler.start_trace``/``stop_trace`` — the hook behind
``benchmarks/serve_throughput.py --profile-dir``.

Every entry degrades to a no-op when the running jax build lacks the
profiler pieces, so annotated library code never gains a hard dependency.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["annotate", "annotation", "trace_capture"]

SCOPE_PREFIX = "kvcomp"


def annotate(name: str):
    """Trace-time scope for jitted code: ops created inside carry
    ``kvcomp/<name>`` into profiles.  Usable as context manager or
    decorator (``jax.named_scope`` supports both)."""
    return jax.named_scope(f"{SCOPE_PREFIX}/{name}")


@contextlib.contextmanager
def annotation(name: str):
    """Runtime (host-side) profiler annotation around a region — shows up
    as a track slice in a captured ``jax.profiler`` trace."""
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    if ta is None:  # profiler build without annotations: free no-op
        yield
        return
    with ta(f"{SCOPE_PREFIX}:{name}"):
        yield


@contextlib.contextmanager
def trace_capture(log_dir: str | None):
    """Capture a ``jax.profiler`` device+host trace into ``log_dir`` for
    the duration of the block; ``None`` disables (the default path costs
    nothing).  Capture failures degrade to a warning-free no-op — CI boxes
    without profiler support must not fail the benchmark around it."""
    if not log_dir:
        yield
        return
    started = False
    try:
        jax.profiler.start_trace(str(log_dir))
        started = True
    except Exception:  # noqa: BLE001 — profiling is best-effort by design
        pass
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:  # noqa: BLE001
                pass
