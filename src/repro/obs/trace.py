"""Structured scheduler event trace with Perfetto-loadable export
(DESIGN.md §14).

Every scheduler decision the Server makes — admit, prefill chunk splice,
page-fault sweep outcome, CoW break, prefix hit/evict, preempt/requeue,
slot retire, token emission — lands here as one tuple in a bounded ring
buffer, stamped with the *same* ``time.monotonic()`` floats the serving
``Result`` is built from.  That identity is the contract: per-request
timings reconstructed from the trace (``request_timings``) equal
``Result.ttft_s`` / ``Result.token_times`` **exactly** (float-for-float,
asserted in ``tests/test_obs.py``), so a Perfetto timeline and a latency
report can never disagree.

Event vocabulary (``kind`` / required payload):

=================  ==========================================================
``submit``         request entered the queue (``t`` = ``Handle._t_submit``)
``admit``          legacy solo admission claimed a slot
``prefill_start``  chunked admission claimed a slot (``hit_blocks`` spliced)
``prefill_chunk``  one chunk dispatch (``dur`` = host dispatch span,
                   ``pos``/``tokens`` = chunk placement)
``prefill_finish`` all forced tokens flushed; row joins the decode batch
``page_assign``    page-fault sweep bound ``page`` to (``row``, ``slot``)
``cow_break``      ring wrap hit a shared page; row re-pointed to a private
                   one (``page`` = the shared page released)
``prefix_hit``     admission lookup matched ``blocks`` cached blocks
``prefix_evict``   admission pressure evicted ``blocks`` index blocks
``preempt``        live row evicted + requeued (``prefilling`` flags a
                   half-prefilled victim)
``retire``         request finished (``reason`` = eos|length)
``fail``           request failed in isolation (``reason`` = "error",
                   ``error`` = the human-readable cause; DESIGN.md §15)
``cancel``         request cancelled via ``Handle.cancel()``
``deadline``       request retired by its deadline (``deadline_s`` = the
                   effective bound it exceeded)
``token``          one generated token (``t`` = its ``token_times`` stamp,
                   ``index`` = its position in the stream)
``decode_step``    one batched decode dispatch (level ``full`` only;
                   ``rows`` = live batch width, ``dur`` = host wall)
=================  ==========================================================

Levels: ``off`` records nothing (the Server skips the call sites entirely —
zero events, zero added dispatches), ``events`` records every scheduler
decision above except the per-step firehose, ``full`` adds ``decode_step``.
The buffer is a ``deque(maxlen=capacity)``: a long run keeps the most
recent window and counts what it dropped instead of growing without bound.

``to_chrome()`` exports the ring as Chrome trace-event JSON ("traceEvents"
array, microsecond timestamps) that loads directly in Perfetto /
``chrome://tracing``: one named track (tid) per request carrying its
queue -> prefill-chunk -> decode spans plus token/preempt instants, and a
``scheduler`` track (tid 0) for row-addressed pool events.
"""

from __future__ import annotations

import collections
import json
import time

__all__ = ["Event", "EventTrace", "TRACE_LEVELS", "EVENT_KINDS"]

TRACE_LEVELS = ("off", "events", "full")

EVENT_KINDS = (
    "submit", "admit", "prefill_start", "prefill_chunk", "prefill_finish",
    "page_assign", "cow_break", "prefix_hit", "prefix_evict",
    "preempt", "retire", "fail", "cancel", "deadline", "token", "decode_step",
)

# Kinds that end a request's lifecycle (DESIGN.md §15 state machine); the
# reconstruction below treats them all as the request's terminal event.
_TERMINAL_KINDS = ("retire", "fail", "cancel", "deadline")

Event = collections.namedtuple("Event", ("t", "kind", "req", "step", "data"))


class EventTrace:
    """Ring-buffered scheduler event log (one per Server)."""

    def __init__(self, level: str = "off", capacity: int = 65536):
        if level not in TRACE_LEVELS:
            raise ValueError(
                f"trace level must be one of {TRACE_LEVELS}, got {level!r}")
        if capacity < 1:
            raise ValueError(f"trace capacity must be >= 1, got {capacity}")
        self.level = level
        self.capacity = int(capacity)
        self.events: collections.deque[Event] = collections.deque(
            maxlen=self.capacity)
        self.emitted = 0  # total ever emitted (dropped = emitted - len)

    @property
    def enabled(self) -> bool:
        return self.level != "off"

    @property
    def full(self) -> bool:
        return self.level == "full"

    @property
    def dropped(self) -> int:
        return self.emitted - len(self.events)

    def emit(self, kind: str, req: int = -1, step: int = -1,
             t: float | None = None, **data) -> None:
        """Record one event.  ``t`` defaults to now; call sites that share a
        stamp with ``Result`` timing (submit/token) pass it explicitly so
        trace and result can never drift apart."""
        self.events.append(Event(
            time.monotonic() if t is None else t, kind, req, step, data))
        self.emitted += 1

    # -- reconstruction -------------------------------------------------------
    def request_timings(self) -> dict:
        """Per-request timing rebuilt purely from the ring: ``{req: {
        "submit", "first_work", "ttft_s", "token_times", "retired",
        "reason"}}``.  Uses the raw monotonic floats, so for any request
        whose full event span is still in the ring these equal the
        ``Result`` fields exactly."""
        out: dict[int, dict] = {}
        for e in self.events:
            if e.req < 0:
                continue
            r = out.setdefault(e.req, {"submit": None, "first_work": None,
                                       "ttft_s": None, "token_times": [],
                                       "retired": False, "reason": None})
            if e.kind == "submit":
                r["submit"] = e.t
            elif e.kind in ("admit", "prefill_start"):
                if r["first_work"] is None:
                    r["first_work"] = e.t
            elif e.kind == "token":
                i = e.data["index"]
                ts = r["token_times"]
                if i == len(ts):
                    ts.append(e.t)
            elif e.kind in _TERMINAL_KINDS:
                r["retired"] = True
                r["reason"] = e.data.get("reason")
        for r in out.values():
            if r["token_times"] and r["submit"] is not None:
                r["ttft_s"] = r["token_times"][0] - r["submit"]
            r["token_times"] = tuple(r["token_times"])
        return out

    # -- Chrome / Perfetto export ---------------------------------------------
    def to_chrome(self, pid: int = 1) -> dict:
        """Chrome trace-event JSON dict: ``json.dump`` it and load the file
        in Perfetto (ui.perfetto.dev) or ``chrome://tracing``.  Requests
        become named threads of one ``kvcomp.server`` process; derived
        spans (queue, prefill, decode) are synthesized from the event pairs
        so the timeline reads without knowing the vocabulary."""
        evs: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "kvcomp.server"},
        }, {
            "name": "thread_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "scheduler"},
        }]

        def us(t: float) -> float:
            return t * 1e6

        # Named per-request tracks.  tid 0 is the scheduler; requests map to
        # tid = req + 1 so request 0 keeps its own lane.
        reqs = sorted({e.req for e in self.events if e.req >= 0})
        for r in reqs:
            evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": r + 1, "args": {"name": f"req {r}"}})

        spans: dict[int, dict] = {r: {} for r in reqs}
        for e in self.events:
            tid = e.req + 1 if e.req >= 0 else 0
            args = {"step": e.step, **e.data}
            if e.kind in ("prefill_chunk", "decode_step"):
                evs.append({"name": e.kind, "ph": "X", "pid": pid, "tid": tid,
                            "ts": us(e.t), "dur": us(e.data.get("dur", 0.0)),
                            "args": args})
                continue
            if e.req >= 0:
                s = spans[e.req]
                if e.kind == "submit":
                    s["submit"] = e.t
                elif e.kind in ("admit", "prefill_start"):
                    s.setdefault("work", e.t)
                elif e.kind == "prefill_finish":
                    s.setdefault("decode", e.t)
                elif e.kind == "token":
                    s.setdefault("decode", e.t)
                    s["last"] = e.t
                elif e.kind in _TERMINAL_KINDS:
                    s["retire"] = e.t
            evs.append({"name": e.kind, "ph": "i", "pid": pid, "tid": tid,
                        "ts": us(e.t), "s": "t", "args": args})

        for r, s in spans.items():
            sub, work = s.get("submit"), s.get("work")
            end = s.get("retire", s.get("last"))
            if sub is not None and work is not None:
                evs.append({"name": "queue", "ph": "X", "pid": pid,
                            "tid": r + 1, "ts": us(sub),
                            "dur": us(work - sub), "args": {}})
            dec = s.get("decode")
            if dec is not None and end is not None and end >= dec:
                evs.append({"name": "decode", "ph": "X", "pid": pid,
                            "tid": r + 1, "ts": us(dec),
                            "dur": us(end - dec), "args": {}})
        return {"traceEvents": evs,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "level": self.level}}

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
