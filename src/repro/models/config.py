"""Model configuration — one dataclass drives every architecture family.

The paper's technique (compressed KV cache) is a first-class config block
(``cache_*`` fields) so any architecture can flip between raw / KIVI /
KVComp-packed caches without touching model code.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10000.0
    # mlp
    d_ff: int = 0
    # structure
    encoder_only: bool = False
    causal: bool = True
    tie_embeddings: bool = False
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm frontend stub)
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # hybrid (Zamba2-style): every `hybrid_period`-th block is a SHARED
    # attention+MLP block; the rest are Mamba2 blocks.
    hybrid_period: int = 0
    # KV-cache compression (the paper's technique).  ``cache_layout`` names
    # a registered repro.core.layouts.CacheLayout; ``cache_overrides`` is a
    # tuple of repro.core.policy.LayerOverride for per-layer deviations.
    cache_layout: str = "packed"  # any name in layouts.available_layouts()
    cache_block: int = 64
    rel_scale_k: float = 0.05
    rel_scale_v: float = 0.15
    kivi_bits: int = 2
    cache_overrides: tuple = ()
    # Cache storage container (DESIGN.md §10): "dense" reserves a full block
    # ring per decode slot; "paged" pools compressed blocks in one shared
    # arena per layer (page-table indirection) so the Server admits by
    # memory pressure and oversubscribes slots.  The pool itself is sized by
    # the Server (ServerConfig.pool_hbm_bytes).
    cache_mode: str = "dense"
    # Decode-attention backend (repro.kernels.ops registry): "auto" runs the
    # fused in-situ-decompression Pallas kernel on TPU for fused-capable
    # layouts and the blockwise-XLA scan elsewhere; "xla"/"fused" pin a path.
    attn_backend: str = "auto"
    # Blockwise-scan tuning (None = REPRO_BLOCKWISE_* env / module default —
    # see repro.core.cache.blockwise_knobs).
    cache_span_tokens: int | None = None
    cache_unroll_max: int | None = None
    # numerics
    dtype: str = "bfloat16"

    def compression_policy(self):
        """The cache_* fields + overrides as one CompressionPolicy."""
        from repro.core.policy import CompressionPolicy, TensorPolicy

        return CompressionPolicy(
            layout=self.cache_layout,
            block_size=self.cache_block,
            k=TensorPolicy(rel_scale=self.rel_scale_k),
            v=TensorPolicy(rel_scale=self.rel_scale_v),
            kivi_bits=self.kivi_bits,
            attn_backend=self.attn_backend,
            mode=self.cache_mode,
            span_tokens=self.cache_span_tokens,
            unroll_max=self.cache_unroll_max,
            overrides=tuple(self.cache_overrides),
        )

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "hybrid")

    @property
    def supports_long_context_decode(self) -> bool:
        """Sub-quadratic long decode: SSM/hybrid natively; SWA via window cap."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        dh = self.resolved_head_dim if self.n_heads else 0
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        mlp_dense = 3 * d * self.d_ff if self.d_ff else 0
        norms = 2 * d
        if self.family == "dense":
            per_layer = attn + mlp_dense + norms
            return emb + self.n_layers * per_layer + d
        if self.family == "moe":
            moe = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
            per_layer = attn + moe + norms
            return emb + self.n_layers * per_layer + d
        if self.family == "ssm":
            di, N, G, H = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            conv_ch = di + 2 * G * N
            per_layer = d * (2 * di + 2 * G * N + H) + conv_ch * self.ssm_conv + di * d + 2 * H + di + d
            return emb + self.n_layers * per_layer + d
        if self.family == "hybrid":
            di, N, G, H = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            conv_ch = di + 2 * G * N
            mamba_layer = d * (2 * di + 2 * G * N + H) + conv_ch * self.ssm_conv + di * d + 2 * H + di + d
            n_attn_positions = self.n_layers // self.hybrid_period
            n_mamba = self.n_layers - n_attn_positions
            shared_attn = attn + mlp_dense + norms  # ONE shared block
            return emb + n_mamba * mamba_layer + shared_attn + d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * self.d_ff_expert
        moe_active = self.n_layers * self.top_k * 3 * d * self.d_ff_expert
        return full - moe_all + moe_active


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-size variant of the same family (CPU-runnable)."""
    base = dict(
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16 if cfg.ssm_state else 128,
        sliding_window=64 if cfg.sliding_window else None,
        hybrid_period=3 if cfg.hybrid_period else 0,
        cache_block=8,
        name=cfg.name + "-smoke",
    )
    if cfg.hybrid_period:
        base["n_layers"] = 7  # 2 periods of 3 + 1 remainder
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
