"""Attention: GQA projections, chunked flash attention (train/prefill), and
decode over the compressed KV cache (the paper's Fetch path).

The train/prefill path is a memory-bounded two-level flash loop (scan over
query chunks, inner scan over KV chunks with running max/denominator), which
keeps peak activation memory at O(S·chunk) instead of O(S²) — required for
the 32k-prefill shapes.  Causal and sliding-window masks are applied per
chunk pair.

Decode attends against a ``repro.core.cache.LayerKVCache`` and appends the
new token's KV — compression is on the hot path exactly as in the paper.
The cache's encoding is whatever ``CacheLayout`` the layer's ``CacheSpec``
names (raw / packed / kivi / huffman / user-registered; DESIGN.md §4), and
per-layer specs arrive from the model's ``CompressionPolicy`` — this module
is layout-agnostic and never branches on the layout name.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import cache as kvcache
from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array

NEG = -1e9


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": layers.dense_init(ks[0], (d, H, Dh), dtype=dtype),
        "wk": layers.dense_init(ks[1], (d, Hkv, Dh), dtype=dtype),
        "wv": layers.dense_init(ks[2], (d, Hkv, Dh), dtype=dtype),
        "wo": layers.dense_init(ks[3], (H, Dh, d), scale=(H * Dh) ** -0.5, dtype=dtype),
    }
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((Dh,), dtype)
        params["k_norm"] = jnp.ones((Dh,), dtype)
        axes["q_norm"] = ("head_dim",)
        axes["k_norm"] = ("head_dim",)
    return params, axes


def qkv_project(params, cfg: ModelConfig, x: Array, positions: Array):
    """x: [B, S, d] -> q [B,S,H,Dh], k/v [B,S,Hkv,Dh] (RoPE'd, qk-normed)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(params, attn_out: Array) -> Array:
    return jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"].astype(attn_out.dtype))


# ---------------------------------------------------------------------------
# chunked flash attention (full-sequence: training / prefill)
# ---------------------------------------------------------------------------


def flash_attention(
    q: Array, k: Array, v: Array,
    *, causal: bool, window: int | None = None,
    q_chunk: int = 512, kv_chunk: int = 512,
    scale: float | None = None,
    unroll: bool = False,
) -> Array:
    """q: [B, S, H, Dh]; k, v: [B, S, Hkv, Dh] (GQA broadcast inside).

    Two-level scan keeps peak memory at O(B·H·q_chunk·kv_chunk).
    """
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(Dh)
    # Snap chunk sizes down to divisors of S (keeps the scan rectangular).
    def _divisor(c):
        c = min(c, S)
        while S % c:
            c -= 1
        return c

    q_chunk = _divisor(q_chunk)
    kv_chunk = _divisor(kv_chunk)
    nq, nk = S // q_chunk, S // kv_chunk

    # [B, n, C, Hkv, G, Dh] query blocks; KV keep Hkv axis.
    qb = q.reshape(B, nq, q_chunk, Hkv, G, Dh)
    kb = k.reshape(B, nk, kv_chunk, Hkv, Dh)
    vb = v.reshape(B, nk, kv_chunk, Hkv, Dh)
    q_pos = jnp.arange(S).reshape(nq, q_chunk)
    k_pos = jnp.arange(S).reshape(nk, kv_chunk)

    def kv_step(qc, qp, carry, kc, vc, kp):
        m, l, acc = carry
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        mask = jnp.ones((qp.shape[0], kp.shape[0]), bool)
        if causal:
            mask &= qp[:, None] >= kp[None, :]
        if window is not None:
            mask &= kp[None, :] > (qp[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        l = l * alpha + jnp.sum(p, axis=-1)
        return m_new, l, acc

    def q_block(qc, qp, j_lo, j_hi):
        """Process one query chunk against kv chunks [j_lo, j_hi)."""
        m = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, q_chunk, Dh), jnp.float32)

        def body(carry, ki):
            kc, vc, kp = ki
            return kv_step(qc, qp, carry, kc, vc, kp), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m, l, acc),
            (kb[:, j_lo:j_hi].transpose(1, 0, 2, 3, 4),
             vb[:, j_lo:j_hi].transpose(1, 0, 2, 3, 4),
             k_pos[j_lo:j_hi]),
            unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,Hkv,G,Cq,Dh]
        return out.transpose(0, 3, 1, 2, 4)  # [B,Cq,Hkv,G,Dh]

    if causal:
        # TRIANGULAR schedule: query chunk i only visits kv chunks whose
        # range intersects [max(0, (i+1)Cq - window), (i+1)Cq) — fully-masked
        # chunk pairs are never materialized, halving causal attention FLOPs
        # (and far more under a sliding window).  Static per-i slices keep
        # everything shape-static (EXPERIMENTS.md #Perf H3, iteration 2).
        outs = []
        for i in range(nq):
            hi_tok = (i + 1) * q_chunk
            j_hi = -(-hi_tok // kv_chunk)  # ceil
            j_lo = 0
            if window is not None:
                lo_tok = max(0, i * q_chunk - window + 1)
                j_lo = lo_tok // kv_chunk
            outs.append(q_block(qb[:, i], q_pos[i], j_lo, j_hi))
        out = jnp.concatenate(outs, axis=1).reshape(B, S, H, Dh)
        return out.astype(q.dtype)

    def q_step(_, qi):
        qc, qp = qi
        return None, q_block(qc, qp, 0, nk)

    _, outs = jax.lax.scan(q_step, None, (qb.transpose(1, 0, 2, 3, 4, 5), q_pos),
                           unroll=unroll)
    # outs: [nq, B, Cq, Hkv, G, Dh] -> [B, S, H, Dh]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, Dh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full attention block (pre-norm attn + residual)
# ---------------------------------------------------------------------------


def init_attn_block(key, cfg: ModelConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    attn_p, attn_a = init_attention(k1, cfg, dtype)
    params = {"attn": attn_p, "ln_attn": jnp.ones((cfg.d_model,), dtype)}
    axes = {"attn": attn_a, "ln_attn": ("embed",)}
    return params, axes


def attn_block_train(params, cfg: ModelConfig, x: Array, positions: Array,
                     q_chunk: int = 512, kv_chunk: int = 512,
                     unroll: bool = False) -> Array:
    h = layers.rms_norm(x, params["ln_attn"], cfg.norm_eps)
    q, k, v = qkv_project(params["attn"], cfg, h, positions)
    o = flash_attention(
        q, k, v, causal=cfg.causal and not cfg.encoder_only,
        window=cfg.sliding_window, q_chunk=q_chunk, kv_chunk=kv_chunk,
        unroll=unroll)
    return x + out_project(params["attn"], o)


def attn_block_prefill(params, cfg: ModelConfig, x: Array, positions: Array,
                       spec: kvcache.CacheSpec,
                       q_chunk: int = 512, kv_chunk: int = 512,
                       unroll: bool = False):
    """Like train, but also builds this layer's compressed cache (Store).
    ``spec`` is this layer's resolved CacheSpec (a CompressionPolicy may
    give every layer a different one)."""
    h = layers.rms_norm(x, params["ln_attn"], cfg.norm_eps)
    q, k, v = qkv_project(params["attn"], cfg, h, positions)
    o = flash_attention(
        q, k, v, causal=cfg.causal and not cfg.encoder_only,
        window=cfg.sliding_window, q_chunk=q_chunk, kv_chunk=kv_chunk,
        unroll=unroll)
    # KV layout for the cache: [B, Hkv, S, Dh]
    cache = kvcache.prefill(spec, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    return x + out_project(params["attn"], o), cache


def attn_block_chunk(params, cfg: ModelConfig, x: Array, positions: Array,
                     cache: kvcache.LayerKVCache):
    """Block-chunked prefill step (prefix-cache admission; DESIGN.md §11):
    process ``C <= block_size`` prompt tokens starting at a block boundary.
    x: [B, C, d]; positions: i32 [B, C] — absolute sequence positions (the
    chunk may resume mid-prompt from cached pages, so RoPE phases never
    restart at zero).

    The chunk attends the compressed store (lazily dequantized, like
    decode) plus its own raw K/V causally, then a full chunk compresses
    straight into the store while a partial tail lands in the raw buffer —
    so each block's output and encoding depend only on (params, earlier
    pages, block tokens), the invariant that makes prefix-cache hits
    bit-identical to chunking from token 0.

    Decode-exact boundary semantics: ``kvcache.append`` flushes a
    block-completing token into the compressed store BEFORE attention runs
    (the token attends itself lossily, with any sliding-window ring
    eviction already applied).  A full chunk therefore splits — the first
    ``T-1`` tokens attend old-store + raw-causal, then the chunk flushes,
    and the boundary token attends the post-flush cache through the same
    ``kvcache.attend`` backend dispatch decode uses.  Without the split, a
    preempt-resume replay of a block-boundary token would attend itself
    raw where the original decode attended it compressed, and the resumed
    greedy tokens would diverge from the uninterrupted run."""
    h = layers.rms_norm(x, params["ln_attn"], cfg.norm_eps)
    q, k, v = qkv_project(params["attn"], cfg, h, positions)
    kT = k.transpose(0, 2, 1, 3)  # [B, Hkv, C, Dh]
    vT = v.transpose(0, 2, 1, 3)
    C = q.shape[1]
    if C == cache.spec.block_size:
        o_head = (kvcache.attend_chunk(cache, q[:, :-1], kT[:, :, :-1],
                                       vT[:, :, :-1]) if C > 1 else None)
        cache = kvcache.append_chunk(cache, kT, vT)
        o_last = kvcache.attend(cache, q[:, -1])[:, None]  # [B, 1, Hq, Dh]
        o = (jnp.concatenate([o_head, o_last], axis=1)
             if o_head is not None else o_last)
    else:
        o = kvcache.attend_chunk(cache, q, kT, vT)
        cache = kvcache.append_chunk(cache, kT, vT)
    return x + out_project(params["attn"], o), cache


def attn_block_decode(params, cfg: ModelConfig, x: Array, position: Array,
                      cache: kvcache.LayerKVCache):
    """One-token decode: append this token's KV (compress-on-overflow) and
    attend over the compressed cache.  x: [B, 1, d]; position: i32 [B] —
    every row of a continuous batch decodes at its own sequence position
    (RoPE, append offset, and attention masks are all per-row).

    ``kvcache.attend`` dispatches through the attention-backend registry
    (DESIGN.md §9) under the spec's ``attn_backend`` (threaded from
    ``ModelConfig``/``CompressionPolicy``): the fused Pallas kernel on TPU,
    the blockwise lazily-dequantized scan elsewhere — the per-row
    ``n_flushed``/``buf_len`` vectors flow into the kernel's scalar-prefetch
    args unchanged."""
    h = layers.rms_norm(x, params["ln_attn"], cfg.norm_eps)
    pos = position.reshape(-1, 1)  # [B, 1]: per-row length-1 seq positions
    q, k, v = qkv_project(params["attn"], cfg, h, pos)
    cache = kvcache.append(cache, k[:, 0], v[:, 0])
    # NB: append puts the token in the raw buffer, so attending *after*
    # appending sees the current token too (self-attention includes self).
    o = kvcache.attend(cache, q[:, 0])  # [B, H, Dh]
    return x + out_project(params["attn"], o[:, None]), cache
