"""Model assembly: dense / MoE / SSM / hybrid / encoder-only, with
scan-over-layers (stacked params keep the HLO small and compile times flat in
depth) and three entry points per model:

  * ``forward``      — full-sequence logits (training / evaluation)
  * ``prefill``      — full-sequence forward that also builds each layer's
                       compressed KV cache (paper Store stage) or SSM state
  * ``decode_step``  — one-token step over the caches (paper Fetch stage)

Params are nested dicts; ``init_params`` returns ``(params, axes)`` where
``axes`` carries logical axis names for the distributed layer.  Stacked layer
params get a leading "layers" logical axis.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import cache as kvcache
from repro.distributed import sharding as shd
from repro.models import attention, layers, moe, ssm
from repro.models.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layers -> leading stacked axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    axes = jax.tree.map(lambda a: ("layers", *a), axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def _dense_block_init(cfg, dtype):
    def f(k):
        k1, k2 = jax.random.split(k)
        ap, aa = attention.init_attn_block(k1, cfg, dtype)
        mp, ma = layers.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        return ({**ap, "mlp": mp, "ln_mlp": jnp.ones((cfg.d_model,), dtype)},
                {**aa, "mlp": ma, "ln_mlp": ("embed",)})
    return f


def _moe_block_init(cfg, dtype):
    def f(k):
        return moe.init_moe_block(k, cfg, dtype)
    return f


def _mamba_block_init(cfg, dtype):
    def f(k):
        return ssm.init_mamba_block(k, cfg, dtype)
    return f


def _hybrid_counts(cfg: ModelConfig):
    period = cfg.hybrid_period
    n_attn = cfg.n_layers // period
    n_periods = n_attn
    tail = cfg.n_layers - n_periods * period
    per_period_mamba = period - 1
    return n_periods, per_period_mamba, tail


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    emb_p, emb_a = layers.init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                         cfg.tie_embeddings, dtype)
    params["emb"], axes["emb"] = emb_p, emb_a
    params["ln_f"] = jnp.ones((cfg.d_model,), dtype)
    axes["ln_f"] = ("embed",)

    if cfg.family == "dense":
        params["blocks"], axes["blocks"] = _stack_init(
            _dense_block_init(cfg, dtype), ks[1], cfg.n_layers)
    elif cfg.family == "moe":
        params["blocks"], axes["blocks"] = _stack_init(
            _moe_block_init(cfg, dtype), ks[1], cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"], axes["blocks"] = _stack_init(
            _mamba_block_init(cfg, dtype), ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        n_periods, ppm, tail = _hybrid_counts(cfg)
        mamba_p, mamba_a = _stack_init(
            _mamba_block_init(cfg, dtype), ks[1], n_periods * ppm)
        params["mamba"] = jax.tree.map(
            lambda x: x.reshape(n_periods, ppm, *x.shape[1:]), mamba_p)
        axes["mamba"] = jax.tree.map(lambda a: ("periods", *a), mamba_a,
                                     is_leaf=lambda x: isinstance(x, tuple))
        if tail:
            params["mamba_tail"], axes["mamba_tail"] = _stack_init(
                _mamba_block_init(cfg, dtype), ks[2], tail)
        # ONE shared attention block (Zamba2's weight-shared attention).
        sa_p, sa_a = _dense_block_init(cfg, dtype)(ks[3])
        params["attn_shared"], axes["attn_shared"] = sa_p, sa_a
    else:
        raise ValueError(cfg.family)
    return params, axes


# ---------------------------------------------------------------------------
# forward (training / evaluation)
# ---------------------------------------------------------------------------


def _embed_input(params, cfg: ModelConfig, batch) -> Array:
    if cfg.input_mode == "tokens":
        return layers.embed_tokens(params["emb"], batch["tokens"])
    return batch["embeddings"]  # audio/vlm frontend stub: precomputed


def _dense_body(cfg, q_chunk, kv_chunk, unroll=False):
    def body(carry, block_p):
        x, positions = carry
        # pin [batch->data] activations: the partitioner otherwise drifts to
        # replicated-batch layouts (and inconsistently across depths, which
        # would also break the roofline extrapolation) — §Perf H3
        x = shd.constrain(x, "__data__", None, None)
        x = attention.attn_block_train(block_p, cfg, x, positions,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk,
                                       unroll=unroll)
        h = layers.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
        x = x + layers.mlp(block_p["mlp"], h)
        return (x, positions), None
    return body


def _moe_body(cfg, q_chunk, kv_chunk, unroll=False):
    def body(carry, block_p):
        x, positions, aux = carry
        x = shd.constrain(x, "__data__", None, None)
        x = attention.attn_block_train(block_p, cfg, x, positions,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk,
                                       unroll=unroll)
        h = layers.rms_norm(x, block_p["ln_moe"], cfg.norm_eps)
        y, a = moe.moe_apply(block_p["moe"], cfg, h)
        return (x + y, positions, aux + a), None
    return body


def _attn_mlp_block(cfg, block_p, x, positions, q_chunk, kv_chunk, unroll=False):
    x = attention.attn_block_train(block_p, cfg, x, positions,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk,
                                   unroll=unroll)
    h = layers.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
    return x + layers.mlp(block_p["mlp"], h)


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            q_chunk: int = 512, kv_chunk: int = 512, unroll: bool = False):
    """Full-sequence forward. Returns (logits [B,S,V], aux dict)."""
    x = _embed_input(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        body = (_moe_body if cfg.family == "moe" else _dense_body)(
            cfg, q_chunk, kv_chunk, unroll)
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if cfg.family == "moe":
            (x, _, aux), _ = jax.lax.scan(body, (x, positions, aux), params["blocks"],
                                          unroll=unroll)
        else:
            (x, _), _ = jax.lax.scan(body, (x, positions), params["blocks"],
                                     unroll=unroll)
    elif cfg.family == "ssm":
        def body(carry, block_p):
            h = shd.constrain(carry, "__data__", None, None)
            return ssm.mamba_block_train(block_p, cfg, h, unroll=unroll), None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
    elif cfg.family == "hybrid":
        def period_body(carry, period_p):
            x, positions = carry
            x = shd.constrain(x, "__data__", None, None)

            def mamba_body(h, bp):
                return ssm.mamba_block_train(bp, cfg, h, unroll=unroll), None

            x, _ = jax.lax.scan(mamba_body, x, period_p, unroll=unroll)
            x = _attn_mlp_block(cfg, params["attn_shared"], x, positions,
                                q_chunk, kv_chunk, unroll)
            return (x, positions), None

        if remat:
            period_body = jax.checkpoint(period_body, prevent_cse=False)
        (x, _), _ = jax.lax.scan(period_body, (x, positions), params["mamba"],
                                 unroll=unroll)
        if "mamba_tail" in params:
            def tail_body(h, bp):
                return ssm.mamba_block_train(bp, cfg, h, unroll=unroll), None
            x, _ = jax.lax.scan(tail_body, x, params["mamba_tail"], unroll=unroll)
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = layers.unembed(params["emb"], x)
    # Keep logits [batch->data, seq, vocab->model]: without this the SPMD
    # partitioner may contract over the FSDP-sharded d_model dim and
    # replicate full logits across the data axis (2x16.8 GB/device of
    # all-gather+all-reduce on yi-6b train — EXPERIMENTS.md #Perf H3 it.1).
    logits = shd.constrain(logits, "__data__", None, "model")
    return logits, {"aux_loss": aux}


# ---------------------------------------------------------------------------
# prefill / decode (serving)
# ---------------------------------------------------------------------------


def n_cache_layers(cfg: ModelConfig) -> int:
    """How many KV caches the decode state holds (hybrid: one per period)."""
    if cfg.family in ("dense", "moe"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return _hybrid_counts(cfg)[0]
    return 0


def cache_specs(cfg: ModelConfig, max_seq: int,
                pool_pages: int = 0) -> tuple[kvcache.CacheSpec, ...]:
    """Per-cache-layer specs resolved from the model's CompressionPolicy.

    ``pool_pages`` sizes the shared paged arena (cache_mode="paged"); with
    the default 0 a paged policy resolves to its dense twin — prefill and
    every non-serving consumer build private dense caches, and only the
    Server (which owns the pool) materializes paged state.
    """
    return cfg.compression_policy().layer_specs(
        n_cache_layers(cfg), max_seq=max_seq, window=cfg.sliding_window,
        pool_pages=pool_pages)


def cache_spec(cfg: ModelConfig, max_seq: int,
               pool_pages: int = 0) -> kvcache.CacheSpec:
    """Layer-0 spec (THE spec under a uniform policy — the common case)."""
    return cfg.compression_policy().spec_for_layer(
        0, max_seq=max_seq, window=cfg.sliding_window, pool_pages=pool_pages)


def _check_nonuniform_supported(cfg: ModelConfig):
    if cfg.family == "hybrid":
        raise NotImplementedError(
            "per-layer cache_overrides are not supported for hybrid models "
            "(all periods share one weight-shared attention block)")


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
                      pool_pages: int = 0):
    """Fresh (empty) decode state for all layers.

    Uniform policies stack the per-layer caches (scan-over-layers keeps the
    HLO small); per-layer overrides give each layer its own spec/shape, so
    the caches are held in a tuple and the layer loop unrolls.
    ``pool_pages`` (serving only) sizes each layer's shared paged arena
    under ``cache_mode="paged"`` — the caches then hold one arena +
    per-row page tables instead of per-row rings (DESIGN.md §10).
    """
    policy = cfg.compression_policy()
    spec = cache_spec(cfg, max_seq, pool_pages)

    def stacked_cache(n):
        one = kvcache.init_layer_cache(
            spec, batch, cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n, *x.shape)), one)

    if cfg.family in ("dense", "moe"):
        if policy.uniform:
            return {"kv": stacked_cache(cfg.n_layers)}
        return {"kv": tuple(
            kvcache.init_layer_cache(s, batch, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, dtype)
            for s in cache_specs(cfg, max_seq, pool_pages))}
    if not policy.uniform:
        _check_nonuniform_supported(cfg)
    if cfg.family == "ssm":
        one = ssm.init_mamba_state(cfg, batch)
        return {"ssm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers, *x.shape)), one)}
    if cfg.family == "hybrid":
        n_periods, ppm, tail = _hybrid_counts(cfg)
        one = ssm.init_mamba_state(cfg, batch)
        state = {
            "kv": stacked_cache(n_periods),
            "ssm": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_periods, ppm, *x.shape)), one),
        }
        if tail:
            state["ssm_tail"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (tail, *x.shape)), one)
        return state
    raise ValueError(cfg.family)


def _insert_leaf(d, s, row):
    if d.shape == s.shape:
        return s
    axis = next(i for i, (a, b) in enumerate(zip(d.shape, s.shape))
                if a != b)
    if s.shape[axis] != 1:
        raise ValueError(f"source state is not batch=1: {s.shape} at axis {axis}")
    return jax.lax.dynamic_update_slice_in_dim(d, s.astype(d.dtype), row, axis)


def insert_decode_row(dst_state, src_state, row):
    """Copy a batch=1 decode state into row ``row`` of a batched state.

    The continuous-batching admission step: a request is prefilled solo
    (exactly its prompt length, no padding) and spliced into a free slot of
    the live decode state while other rows keep decoding.  Works for every
    family/state layout: for each leaf pair, the batch axis is the first axis
    where the two shapes differ (the source has extent 1 there); leaves with
    identical shapes (layout dummies, or a one-slot server) are taken from
    the source wholesale.  ``row`` may be traced (one jit compilation covers
    every slot).
    """
    return jax.tree.map(lambda d, s: _insert_leaf(d, s, row),
                        dst_state, src_state)


def insert_decode_row_paged(dst_state, src_state, row, pages):
    """Paged admission splice (DESIGN.md §10).

    Like ``insert_decode_row``, but the live KV caches are paged (one
    shared arena + page tables) while the solo prefill ``src_state`` is
    their *dense twin* — so the KV splice scatters the solo cache's blocks
    into the arena pages the scheduler allocated (``pages``: i32 [NB],
    physical page for logical block i, -1 where the prompt left the slot
    empty) and writes the page-table row, via ``pool.splice_row``.  Every
    non-KV leaf (buffers ride inside the caches; SSM states for hybrids)
    takes the generic per-leaf batch-axis splice.  ``row`` may be traced.
    """
    from repro.core import pool

    out = {}
    for key, dval in dst_state.items():
        sval = src_state[key]
        if key == "kv":
            if isinstance(dval, (tuple, list)):
                out[key] = tuple(pool.splice_row(d, s, row, pages)
                                 for d, s in zip(dval, sval))
            else:
                out[key] = pool.splice_row(dval, sval, row, pages)
        else:
            out[key] = jax.tree.map(lambda d, s: _insert_leaf(d, s, row),
                                    dval, sval)
    return out


def _map_kv(state, fn):
    kv = state["kv"]
    kv = (tuple(fn(c) for c in kv) if isinstance(kv, (tuple, list))
          else fn(kv))
    return {**state, "kv": kv}


def assign_cache_pages(state, rows, slots, pages):
    """Point ``page_tab[rows[i], slots[i]] = pages[i]`` in every layer's
    cache (padded entries use rows = -1 and drop).  The scheduler calls
    this right before the decode step whose buffer flush lands in those
    pages."""
    from repro.core import pool

    return _map_kv(state, lambda c: pool.assign_pages(c, rows, slots, pages))


def clear_cache_row(state, row):
    """Unassign row ``row``'s pages in every layer's page table (retire /
    preempt): later garbage flushes from the vacated slot drop instead of
    corrupting pages re-issued to another request."""
    from repro.core import pool

    return _map_kv(state, lambda c: pool.clear_row(c, row))


def prefill(params, cfg: ModelConfig, batch, max_seq: int,
            q_chunk: int = 512, kv_chunk: int = 512, unroll: bool = False):
    """Process a prompt; returns (logits [B,S,V], decode_state)."""
    x = _embed_input(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    policy = cfg.compression_policy()
    if not policy.uniform:
        _check_nonuniform_supported(cfg)
    spec = cache_spec(cfg, max_seq)

    if cfg.family in ("dense", "moe"):
        def ffn(block_p, x):
            if cfg.family == "moe":
                h = layers.rms_norm(x, block_p["ln_moe"], cfg.norm_eps)
                y, _ = moe.moe_apply(block_p["moe"], cfg, h)
                return x + y
            h = layers.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
            return x + layers.mlp(block_p["mlp"], h)

        def body(carry, block_p, layer_spec=spec):
            x, positions = carry
            x, cache = attention.attn_block_prefill(
                block_p, cfg, x, positions, layer_spec, q_chunk, kv_chunk, unroll)
            x = ffn(block_p, x)
            return (x, positions), cache

        if policy.uniform:
            (x, _), caches = jax.lax.scan(body, (x, positions), params["blocks"],
                                          unroll=unroll)
            state = {"kv": caches}
        else:
            # Per-layer specs give per-layer cache shapes: unrolled loop.
            caches = []
            for i, layer_spec in enumerate(cache_specs(cfg, max_seq)):
                block_p = jax.tree.map(lambda p: p[i], params["blocks"])
                (x, _), cache = body((x, positions), block_p, layer_spec)
                caches.append(cache)
            state = {"kv": tuple(caches)}
    elif cfg.family == "ssm":
        def body(carry, block_p):
            out, st = ssm.mamba_block_prefill(block_p, cfg, carry, unroll=unroll)
            return out, st
        x, states = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
        state = {"ssm": states}
    elif cfg.family == "hybrid":
        def period_body(carry, period_p):
            x, positions = carry

            def mamba_body(h, bp):
                out, st = ssm.mamba_block_prefill(bp, cfg, h, unroll=unroll)
                return out, st

            x, sstates = jax.lax.scan(mamba_body, x, period_p, unroll=unroll)
            x, cache = attention.attn_block_prefill(
                params["attn_shared"], cfg, x, positions, spec, q_chunk, kv_chunk,
                unroll)
            h = layers.rms_norm(x, params["attn_shared"]["ln_mlp"], cfg.norm_eps)
            x = x + layers.mlp(params["attn_shared"]["mlp"], h)
            return (x, positions), (sstates, cache)

        (x, _), (sstates, caches) = jax.lax.scan(period_body, (x, positions),
                                                 params["mamba"], unroll=unroll)
        state = {"kv": caches, "ssm": sstates}
        if "mamba_tail" in params:
            def tail_body(h, bp):
                out, st = ssm.mamba_block_prefill(bp, cfg, h, unroll=unroll)
                return out, st
            x, tstates = jax.lax.scan(tail_body, x, params["mamba_tail"], unroll=unroll)
            state["ssm_tail"] = tstates
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = layers.unembed(params["emb"], x)
    return logits, state


def decode_step(params, cfg: ModelConfig, tokens, position, state,
                unroll: bool = False):
    """One decode step.  tokens: [B] ids (or [B, d] embeddings);
    position: i32 [B] — each row's current sequence length.  Rows advance
    independently (the continuous-batching contract); a scalar broadcasts to
    the uniform lockstep case.  Returns (logits [B, V], new state)."""
    position = jnp.asarray(position, jnp.int32)
    if position.ndim == 0:
        position = jnp.broadcast_to(position, (tokens.shape[0],))
    if cfg.input_mode == "tokens":
        x = layers.embed_tokens(params["emb"], tokens[:, None])
    else:
        x = tokens[:, None, :]

    if cfg.family in ("dense", "moe"):
        def body(carry, xs):
            x = carry
            block_p, cache = xs
            x, cache = attention.attn_block_decode(block_p, cfg, x, position, cache)
            if cfg.family == "moe":
                h = layers.rms_norm(x, block_p["ln_moe"], cfg.norm_eps)
                y, _ = moe.moe_apply(block_p["moe"], cfg, h)
                x = x + y
            else:
                h = layers.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
                x = x + layers.mlp(block_p["mlp"], h)
            return x, cache

        if isinstance(state["kv"], (tuple, list)):
            # Per-layer cache specs (CompressionPolicy overrides): unrolled.
            caches = []
            for i, cache in enumerate(state["kv"]):
                block_p = jax.tree.map(lambda p: p[i], params["blocks"])
                x, cache = body(x, (block_p, cache))
                caches.append(cache)
            new_state = {"kv": tuple(caches)}
        else:
            x, caches = jax.lax.scan(body, x, (params["blocks"], state["kv"]),
                                     unroll=unroll)
            new_state = {"kv": caches}
    elif cfg.family == "ssm":
        def body(carry, xs):
            block_p, st = xs
            out, st = ssm.mamba_block_decode(block_p, cfg, carry, st)
            return out, st
        x, states = jax.lax.scan(body, x, (params["blocks"], state["ssm"]),
                                 unroll=unroll)
        new_state = {"ssm": states}
    elif cfg.family == "hybrid":
        def period_body(carry, xs):
            x = carry
            period_p, sstates, cache = xs

            def mamba_body(h, inner):
                bp, st = inner
                out, st = ssm.mamba_block_decode(bp, cfg, h, st)
                return out, st

            x, sstates = jax.lax.scan(mamba_body, x, (period_p, sstates),
                                      unroll=unroll)
            x, cache = attention.attn_block_decode(
                params["attn_shared"], cfg, x, position, cache)
            h = layers.rms_norm(x, params["attn_shared"]["ln_mlp"], cfg.norm_eps)
            x = x + layers.mlp(params["attn_shared"]["mlp"], h)
            return x, (sstates, cache)

        x, (sstates, caches) = jax.lax.scan(
            period_body, x, (params["mamba"], state["ssm"], state["kv"]),
            unroll=unroll)
        new_state = {"kv": caches, "ssm": sstates}
        if "mamba_tail" in params:
            def tail_body(h, xs):
                bp, st = xs
                out, st = ssm.mamba_block_decode(bp, cfg, h, st)
                return out, st
            x, tstates = jax.lax.scan(tail_body, x,
                                      (params["mamba_tail"], state["ssm_tail"]),
                                      unroll=unroll)
            new_state["ssm_tail"] = tstates
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = layers.unembed(params["emb"], x[:, 0])
    return logits, new_state


def gather_prefix_state(state, pages, n_blocks):
    """Seed a solo (batch-1, dense) decode state from cached arena pages —
    the prefix-cache hit path (DESIGN.md §11).  ``pages``: i32 [NB], the
    physical page of logical block ``i`` for the first ``n_blocks`` blocks
    (-1 padding beyond); ``n_blocks`` may be traced.  Every layer's cache
    gathers its blocks bit-for-bit out of the live arena with an empty raw
    buffer, so ``prefill_chunk`` resumes at block ``n_blocks`` exactly as
    if it had chunked the whole prefix itself.  KV-only families (dense /
    moe) — the scheduler enforces this before enabling the prefix cache."""
    from repro.core import pool

    kv = state["kv"]
    fn = lambda c: pool.gather_pages(c, pages, n_blocks)  # noqa: E731
    return {"kv": tuple(fn(c) for c in kv) if isinstance(kv, (tuple, list))
            else fn(kv)}


def _map_kv_pair(state, other, fn):
    kv, okv = state["kv"], other["kv"]
    if isinstance(kv, (tuple, list)):
        return {**state, "kv": tuple(fn(d, s) for d, s in zip(kv, okv))}
    return {**state, "kv": fn(kv, okv)}


def chunk_state_view(state, pages, pos0):
    """Batch-1 view of one row's chunked prefill over the LIVE paged state
    (DESIGN.md §13): every layer's cache shares the arena stores, so
    ``prefill_chunk`` on the view encodes each chunk's blocks straight into
    the pooled pages ``pages`` (i32 [NB]) while the batched decode state is
    untouched.  KV-only families (the scheduler gates chunked admission on
    this)."""
    from repro.core import pool

    kv = state["kv"]
    fn = lambda c: pool.chunk_view(c, pages, pos0)  # noqa: E731
    return {"kv": tuple(fn(c) for c in kv) if isinstance(kv, (tuple, list))
            else fn(kv)}


def adopt_chunk_stores(state, chunked):
    """Fold a chunk step's arena-store updates (made through a
    ``chunk_state_view``) back into the live batched state."""
    from repro.core import pool

    return _map_kv_pair(state, chunked, pool.adopt_stores)


def install_chunk_row(state, chunked, row, pages):
    """Finish a chunked prefill: adopt the final view's arena stores, splice
    its buffers/lengths into row ``row``, and point the page-table row at
    ``pages`` — the moment the row becomes attendable by the decode batch."""
    from repro.core import pool

    return _map_kv_pair(state, chunked,
                        lambda d, s: pool.install_row(d, s, row, pages))


def prefill_chunk(params, cfg: ModelConfig, tokens, pos0, state,
                  unroll: bool = False):
    """One block-chunked prefill step (prefix-cache admission path;
    DESIGN.md §11).  tokens: i32 [B, C] — up to ``block_size`` prompt
    tokens starting at the block-boundary position ``pos0`` (scalar or
    [B]); ``state`` is a solo decode state whose caches sit exactly at that
    boundary (raw buffers empty) — fresh, mid-chunking, or seeded from
    cached pages by ``gather_prefix_state``.  Returns (logits [B, V] of the
    chunk's LAST token, new state).

    Each chunk attends the compressed store plus its own raw K/V causally
    and then compresses itself (``attention.attn_block_chunk``), so the
    computation per block is a pure function of (params, earlier blocks'
    pages, block tokens) — chunking a suffix after a prefix-cache hit is
    bit-identical to chunking from token 0, which is what lets greedy
    outputs match between sharing-on and sharing-off servers."""
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            "block-chunked prefill needs pure-KV decode state "
            f"(family {cfg.family!r})")
    B, C = tokens.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    if pos0.ndim == 0:
        pos0 = jnp.broadcast_to(pos0, (B,))
    positions = pos0[:, None] + jnp.arange(C)[None, :]
    x = layers.embed_tokens(params["emb"], tokens)

    def body(carry, xs):
        x = carry
        block_p, cache = xs
        x, cache = attention.attn_block_chunk(block_p, cfg, x, positions, cache)
        if cfg.family == "moe":
            h = layers.rms_norm(x, block_p["ln_moe"], cfg.norm_eps)
            y, _ = moe.moe_apply(block_p["moe"], cfg, h)
            x = x + y
        else:
            h = layers.rms_norm(x, block_p["ln_mlp"], cfg.norm_eps)
            x = x + layers.mlp(block_p["mlp"], h)
        return x, cache

    if isinstance(state["kv"], (tuple, list)):
        # Per-layer cache specs (CompressionPolicy overrides): unrolled.
        caches = []
        for i, cache in enumerate(state["kv"]):
            block_p = jax.tree.map(lambda p: p[i], params["blocks"])
            x, cache = body(x, (block_p, cache))
            caches.append(cache)
        new_state = {"kv": tuple(caches)}
    else:
        x, caches = jax.lax.scan(body, x, (params["blocks"], state["kv"]),
                                 unroll=unroll)
        new_state = {"kv": caches}

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = layers.unembed(params["emb"], x[:, -1])
    return logits, new_state


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(params, cfg: ModelConfig, batch, *, remat: bool = False,
            q_chunk: int = 512, kv_chunk: int = 512, unroll: bool = False):
    """Next-token cross entropy (tokens mode) or frame CE (encoder mode)."""
    logits, aux = forward(params, cfg, batch, remat=remat,
                          q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
    labels = batch["labels"]
    lf = shd.constrain(logits.astype(jnp.float32), "__data__", None, "model")
    # One-hot contraction instead of take_along_axis: with vocab sharded on
    # the model axis, a gather forces the SPMD partitioner to all-reduce the
    # FULL [B, S, V/shard] logits (16.8 GB/device on yi-6b train_4k); the
    # one-hot sum reduces over the sharded vocab dim -> a [B, S] psum
    # (EXPERIMENTS.md #Perf H3, iteration 1).
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=jnp.float32)
    logit_at_label = jnp.sum(lf * onehot, axis=-1)
    ll = logit_at_label - jax.nn.logsumexp(lf, axis=-1)
    mask = batch.get("mask", jnp.ones_like(ll))
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux["aux_loss"], {"ce": loss, **aux}
