"""Shared neural building blocks (pure-function style, no framework).

Parameters live in nested dicts of jnp arrays.  Every ``init_*`` returns
``(params, axes)`` where ``axes`` mirrors the params tree with a tuple of
*logical axis names* per array dimension — the distributed layer
(``repro.distributed.sharding``) maps logical names to mesh axes per run
mode (train=FSDP×TP, serve=TP), MaxText-style.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = fan_in**-0.5
    return jax.random.normal(key, shape, dtype) * scale


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_gated(x: Array, gate: Array, scale: Array, eps: float = 1e-5) -> Array:
    """Mamba2's RMSNormGated: normalize(x * silu(gate)) * scale."""
    xf = (x * jax.nn.silu(gate)).astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, Dh] (or [..., H, Dh] with scalar position).

    positions broadcasts against the S axis.  Rotation pairs are
    (x[..., :half], x[..., half:]) — the Llama convention.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = rope_frequencies(dh, theta)  # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }
    axes = {
        "w_gate": ("embed", "mlp"),
        "w_up": ("embed", "mlp"),
        "w_down": ("mlp", "embed"),
    }
    return params, axes


def mlp(params, x: Array) -> Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, tie: bool, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    params = {"embed": embed_init(k1, (vocab, d_model), dtype)}
    axes = {"embed": ("vocab", "embed")}
    if not tie:
        params["unembed"] = dense_init(k2, (d_model, vocab), dtype=dtype)
        axes["unembed"] = ("embed", "vocab")
    return params, axes


def embed_tokens(params, ids: Array) -> Array:
    return params["embed"][ids]


def unembed(params, x: Array) -> Array:
    from repro.distributed import sharding as shd

    # Force the FSDP(d_model)-sharded table to be gathered (65 MB) rather
    # than letting the partitioner contract over the sharded dim, which
    # replicates full [B, S, V/shard] logits across the data axis
    # (2x16.8 GB/device on yi-6b train_4k — EXPERIMENTS.md #Perf H3 it.1).
    if "unembed" in params:
        w = shd.constrain(params["unembed"], None, "model")
        return x @ w
    w = shd.constrain(params["embed"], "model", None)
    return x @ w.T
