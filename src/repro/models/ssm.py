"""Mamba2 (state-space duality) mixer — chunked SSD for training/prefill and
constant-state recurrence for decode.

The SSD chunked algorithm is expressed as batched matmuls (MXU-shaped):
intra-chunk attention-like term + inter-chunk state recurrence (lax.scan).
Decode keeps a fixed [B, H, N, P] state and a small causal-conv window —
no KV cache at all, which is why mamba2 is listed "inapplicable" for the
paper's technique in DESIGN.md §4 and runs long_500k natively.

Shapes: d_inner = expand·d_model, P = ssm_head_dim, H = d_inner/P heads,
N = ssm_state, G = ssm_groups (B/C shared per group).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    P = cfg.ssm_head_dim
    H = di // P
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_ch = di + 2 * G * N
    return di, P, H, N, G, conv_ch


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, P, H, N, G, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    params = {
        "ln": jnp.ones((d,), dtype),
        "in_proj": layers.dense_init(ks[0], (d, 2 * di + 2 * G * N + H), dtype=dtype),
        "conv_w": layers.dense_init(ks[1], (cfg.ssm_conv, conv_ch), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.ones((di,), dtype),
        "out_proj": layers.dense_init(ks[2], (di, d), dtype=dtype),
    }
    axes = {
        "ln": ("embed",),
        "in_proj": ("embed", "ssm_proj"),
        "conv_w": ("conv_k", "ssm_conv_ch"),
        "conv_b": ("ssm_conv_ch",),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, axes


def _split_proj(cfg: ModelConfig, proj: Array):
    di, P, H, N, G, _ = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along S. xBC: [B, S, C]; w: [K, C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def ssd_scan(x: Array, a: Array, dt: Array, B: Array, C: Array, chunk: int,
             h0: Array | None = None, unroll: bool = False):
    """Core SSD: h_s = exp(a_s)·h_{s-1} + dt_s·B_s⊗x_s ;  y_s = C_s·h_s.

    x : [b, S, H, P]      a : [b, S, H] (log decay = dt·A, negative)
    dt: [b, S, H]         B, C : [b, S, G, N]
    h0: optional [b, H, N, P] initial state.
    Returns (y [b, S, H, P], h_final [b, H, N, P]).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    NC = S // Q
    hpg = H // G

    xc = x.reshape(b, NC, Q, H, P)
    ac = a.reshape(b, NC, Q, H).astype(jnp.float32)
    dtc = dt.reshape(b, NC, Q, H).astype(jnp.float32)
    Bc = B.reshape(b, NC, Q, G, N).astype(jnp.float32)
    Cc = C.reshape(b, NC, Q, G, N).astype(jnp.float32)

    cum = jnp.cumsum(ac, axis=2)  # [b,NC,Q,H]
    # --- intra-chunk (diagonal blocks) ---
    # Gmat[b,c,g,i,j] = C_i · B_j ; broadcast group -> heads later.
    Gmat = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)
    # L[b,c,h,i,j] = exp(cum_i - cum_j) for i >= j else 0.
    # The mask must be applied INSIDE the exp: for masked (i < j) entries the
    # exponent is positive and can overflow to inf, and grad-of-where would
    # then produce inf*0 = NaN in the backward pass.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,c,i,j,h]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    # weights W[b,c,i,j,h] = G[...(g(h))...] * L * dt_j
    Gh = jnp.repeat(Gmat, hpg, axis=2)  # [b,c,H,i,j]
    W = Gh.transpose(0, 1, 3, 4, 2) * L * dtc[:, :, None, :, :]  # [b,c,i,j,h]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(jnp.float32))

    # --- per-chunk states: S_c = Σ_j exp(cum_Q - cum_j)·dt_j·B_j⊗x_j ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [b,c,Q,h]
    Bh = jnp.repeat(Bc, hpg, axis=3).reshape(b, NC, Q, H, N)
    S_c = jnp.einsum("bcjhn,bcjhp->bchnp", Bh,
                     xc.astype(jnp.float32) * (dtc * decay_to_end)[..., None])
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [b,NC,H]

    # --- inter-chunk recurrence ---
    def step(h, inputs):
        dec, s_c = inputs  # [b,H], [b,H,N,P]
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h  # emit PREVIOUS state (used by chunk c)

    h_init = jnp.zeros((b, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_final, h_prev = jax.lax.scan(
        step, h_init, (chunk_decay.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)),
        unroll=unroll)
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # [b,NC,H,N,P]

    # --- inter-chunk contribution ---
    Ch = jnp.repeat(Cc, hpg, axis=3).reshape(b, NC, Q, H, N)
    y_off = jnp.einsum("bcihn,bchnp->bcihp", Ch * jnp.exp(cum)[..., None], h_prev)

    y = (y_diag + y_off).reshape(b, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_reference(x, a, dt, B, C, h0=None):
    """O(S) sequential oracle for ssd_scan (tests)."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    Bh = jnp.repeat(B, hpg, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, hpg, axis=2).astype(jnp.float32)

    def step(h, inp):
        xs, as_, dts, Bs, Cs = inp  # [b,H,P],[b,H],[b,H],[b,H,N],[b,H,N]
        h = h * jnp.exp(as_)[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bs, xs.astype(jnp.float32) * dts[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", Cs, h)
        return h, y

    h_init = jnp.zeros((b, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h, ys = jax.lax.scan(
        step, h_init,
        (x.transpose(1, 0, 2, 3), a.astype(jnp.float32).transpose(1, 0, 2),
         dt.astype(jnp.float32).transpose(1, 0, 2),
         Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


# ---------------------------------------------------------------------------
# full mixer (train / prefill / decode)
# ---------------------------------------------------------------------------


def mamba_block_train(params, cfg: ModelConfig, u: Array, unroll: bool = False):
    """u: [B, S, d] -> [B, S, d] (residual included)."""
    di, P, H, N, G, conv_ch = _dims(cfg)
    x_in = layers.rms_norm(u, params["ln"], cfg.norm_eps)
    proj = x_in @ params["in_proj"].astype(x_in.dtype)
    z, xBC, dt_raw = _split_proj(cfg, proj)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(xBC.dtype),
                                   params["conv_b"].astype(xBC.dtype)))
    xs, Bs, Cs = jnp.split(xBC, [di, di + G * N], axis=-1)
    b, S = u.shape[0], u.shape[1]
    xh = xs.reshape(b, S, H, P)
    Bh = Bs.reshape(b, S, G, N)
    Ch = Cs.reshape(b, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H]
    a = dt * A[None, None, :]
    y, _ = ssd_scan(xh, a, dt, Bh, Ch, cfg.ssm_chunk, unroll=unroll)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, S, di).astype(u.dtype)
    y = layers.rms_norm_gated(y, z, params["norm"], cfg.norm_eps)
    return u + (y @ params["out_proj"].astype(u.dtype))


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, P, H, N, G, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba_block_prefill(params, cfg: ModelConfig, u: Array, unroll: bool = False):
    """Forward over the prompt, returning the decode state."""
    di, P, H, N, G, conv_ch = _dims(cfg)
    x_in = layers.rms_norm(u, params["ln"], cfg.norm_eps)
    proj = x_in @ params["in_proj"].astype(x_in.dtype)
    z, xBC, dt_raw = _split_proj(cfg, proj)
    xBC_conv = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(xBC.dtype),
                                        params["conv_b"].astype(xBC.dtype)))
    xs, Bs, Cs = jnp.split(xBC_conv, [di, di + G * N], axis=-1)
    b, S = u.shape[0], u.shape[1]
    xh = xs.reshape(b, S, H, P)
    Bh = Bs.reshape(b, S, G, N)
    Ch = Cs.reshape(b, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = dt * A[None, None, :]
    y, h_final = ssd_scan(xh, a, dt, Bh, Ch, cfg.ssm_chunk, unroll=unroll)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, S, di).astype(u.dtype)
    y = layers.rms_norm_gated(y, z, params["norm"], cfg.norm_eps)
    out = u + (y @ params["out_proj"].astype(u.dtype))
    K = cfg.ssm_conv
    state = {
        "conv": xBC[:, max(0, S - (K - 1)) :, :] if S >= K - 1 else jnp.pad(
            xBC, ((0, 0), (K - 1 - S, 0), (0, 0))),
        "ssm": h_final,
    }
    return out, state


def mamba_block_decode(params, cfg: ModelConfig, u: Array, state: dict):
    """One-token decode. u: [B, 1, d]; state from init/prefill."""
    di, P, H, N, G, conv_ch = _dims(cfg)
    x_in = layers.rms_norm(u, params["ln"], cfg.norm_eps)
    proj = x_in @ params["in_proj"].astype(x_in.dtype)  # [B,1,*]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    # conv over stored window + this token
    window = jnp.concatenate([state["conv"].astype(xBC.dtype), xBC], axis=1)  # [B,K,C]
    w = params["conv_w"].astype(xBC.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(xBC.dtype)
    xBC_t = jax.nn.silu(conv_out)[:, None, :]
    xs, Bs, Cs = jnp.split(xBC_t, [di, di + G * N], axis=-1)
    b = u.shape[0]
    xh = xs.reshape(b, H, P)
    Bh = jnp.repeat(Bs.reshape(b, G, N), H // G, axis=1)
    Ch = jnp.repeat(Cs.reshape(b, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    h = state["ssm"] * jnp.exp(dt * A[None])[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh.astype(jnp.float32), xh.astype(jnp.float32) * dt[..., None])
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = layers.rms_norm_gated(y, z, params["norm"], cfg.norm_eps)
    out = u + (y @ params["out_proj"].astype(u.dtype))
    new_state = {"conv": window[:, 1:, :], "ssm": h}
    return out, new_state
