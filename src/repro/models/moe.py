"""Mixture-of-Experts layer: top-k router + sort-based grouped expert matmul.

Dispatch avoids the O(tokens × E × capacity) one-hot einsum of Switch-style
implementations: tokens are argsorted by expert id, ranked within their
expert's run (cumulative-max trick), and scattered into a dense
``[E, capacity, d]`` buffer that the per-expert matmuls consume.  Overflowing
tokens are dropped (standard capacity-factor semantics) and their combine
weight contributes nothing.

Sharding: the expert axis (logical name "experts") maps to the mesh "model"
axis when E ≥ |model| (qwen3-moe: 128 experts → EP); otherwise the expert FF
dim shards (mixtral: 8 experts → TP-within-expert).  Both are just different
rows in the logical-axis rule table — see repro.distributed.sharding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    d, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    params = {
        "router": layers.dense_init(ks[0], (d, E), dtype=dtype),
        "w_gate": layers.dense_init(ks[1], (E, d, F), dtype=dtype),
        "w_up": layers.dense_init(ks[2], (E, d, F), dtype=dtype),
        "w_down": layers.dense_init(ks[3], (E, F, d), dtype=dtype),
    }
    axes = {
        "router": ("embed", "experts_r"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    return params, axes


def moe_apply(params, cfg: ModelConfig, x: Array):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, d)

    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # [N, E]
    top_p, top_e = jax.lax.top_k(probs, K)  # [N, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize top-k

    # Load-balance auxiliary loss (Switch-style): E * mean(frac_i * prob_i).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0) / K
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    # ---- sort-based dispatch -------------------------------------------------
    C = int(max(1, -(-N * K // E) * cfg.capacity_factor))
    flat_e = top_e.reshape(-1)  # [N*K]
    flat_tok = jnp.repeat(jnp.arange(N), K)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    idx = jnp.arange(N * K)
    is_start = jnp.concatenate([jnp.ones(1, bool), se[1:] != se[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    rank = idx - seg_start  # position within this expert's run
    keep = rank < C
    dest_e = jnp.where(keep, se, E)       # E = drop sentinel
    dest_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E, C, d), xf.dtype)
    buf = buf.at[dest_e, dest_c].set(xf[stok], mode="drop")

    # ---- per-expert SwiGLU ---------------------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(buf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(buf.dtype))
    yb = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(buf.dtype))

    # ---- combine --------------------------------------------------------------
    # Scatter slot->token directly from the E-sharded buffer.  The naive
    # gather-then-scatter (yb[dest_e, dest_c] -> [N*K, d]) makes the SPMD
    # partitioner all-reduce an [N*K, d] partial-gather tensor across the
    # expert axis; writing each slot's weighted output straight into y keeps
    # the cross-shard reduction at [N, d] — K× fewer bytes (§Perf H2).
    slot_tok = jnp.full((E, C), N, jnp.int32).at[dest_e, dest_c].set(
        stok.astype(jnp.int32), mode="drop")
    slot_w = jnp.zeros((E, C), xf.dtype).at[dest_e, dest_c].set(
        (sw * keep).astype(xf.dtype), mode="drop")
    contrib = (yb * slot_w[..., None]).reshape(E * C, d)
    y = jnp.zeros((N, d), xf.dtype).at[slot_tok.reshape(-1)].add(
        contrib.astype(xf.dtype), mode="drop")
    return y.reshape(B, S, d), aux


def init_moe_block(key, cfg: ModelConfig, dtype=jnp.float32):
    """Pre-norm attention + MoE FFN block params."""
    from repro.models import attention

    k1, k2, k3 = jax.random.split(key, 3)
    attn_p, attn_a = attention.init_attn_block(k1, cfg, dtype)
    moe_p, moe_a = init_moe(k2, cfg, dtype)
    params = {**attn_p, "moe": moe_p, "ln_moe": jnp.ones((cfg.d_model,), dtype)}
    axes = {**attn_a, "moe": moe_a, "ln_moe": ("embed",)}
    return params, axes
