"""Architecture registry: maps ``--arch <id>`` to its ModelConfig (+ the
reduced smoke variant) by importing ``repro.configs.<id>`` modules."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

ARCHS = [
    "mixtral_8x22b",
    "qwen3_moe_30b_a3b",
    "yi_6b",
    "qwen3_1_7b",
    "command_r_35b",
    "stablelm_12b",
    "chameleon_34b",
    "hubert_xlarge",
    "mamba2_1_3b",
    "zamba2_7b",
    # paper's own evaluation models (reduced-scale fidelity configs)
    "llama2_7b",
    "llama2_13b",
    "ministral_8b",
]

ASSIGNED = ARCHS[:10]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name in ARCHS:
        return name
    if name in _ALIAS:
        return _ALIAS[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return getattr(mod, "SMOKE", None) or reduced(mod.CONFIG)
