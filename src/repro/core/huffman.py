"""Canonical Huffman coding for quantization codes (paper §3.1.2, §3.3.1).

The paper builds *shared per-layer codebooks* once during prefill (host side)
and reuses them during decode.  We keep that split:

* ``build_codebook`` — host-side (numpy + heapq) from a device histogram;
  canonical, deterministic, length-limited to ``MAX_CODE_LEN`` bits.
* ``CodeBook`` — lengths/codewords plus the *array-based tree* used by the
  paper's branch-divergence-free decoder (children indices + is_symbol flags;
  traditional pointers replaced by node-array indexes).
* ``encode_block`` / ``decode_block`` — numpy oracles: one "stream" per row
  (the per-thread unit in the paper), streams tightly bit-packed in order with
  per-stream bit counts (u16) as metadata.
* ``encode_block_jax`` / ``decode_block_jax`` — jit-friendly equivalents.
  Encoding computes every symbol's bit offset with an exclusive cumsum (the
  TPU-native replacement for the paper's CUB inclusive scan + global atomic:
  offsets are fully deterministic, so no write races exist by construction).
  Decoding is the paper's branchless tree walk, vectorized across streams
  (one VPU lane plays the role of one CUDA thread).  ``walk_decode_jax`` is
  the kernel-safe core of that walk — the SAME function runs inside the
  Pallas decode kernels (``repro.kernels.huffman_decode``) and in the
  vmapped jnp oracles, so kernel and oracle cannot drift.
* ``build_decode_lut`` / ``decode_block_lut_jax`` — the chunked
  direct-lookup decoder (DESIGN.md §9).  Canonical length-limited codes
  (``MAX_CODE_LEN`` = 16) admit a per-state 8-bit-chunk LUT: entry
  ``[node, chunk]`` records the first symbol reached walking ``chunk``'s
  bits from ``node`` (symbol, bits consumed, emitted flag, continuation
  node), so one symbol decodes in at most ``ceil(max_code_len / 8)`` ≤ 2
  table probes instead of up to 16 bit-serial tree steps.  This is the
  decode the huffman cache layout runs — inside the fused attention kernel
  and in the blockwise XLA floor alike.

Bit order: LSB-first within little-endian u32 words — global bit position p
lives at word ``p >> 5``, bit ``p & 31``.  Codewords are emitted
first-transmitted-bit-in-LSB, so the encoder ORs ``code_lsb << (p & 31)``.
"""

from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

N_SYMBOLS = 256
MAX_CODE_LEN = 16
# Worst-case encoded bits per symbol given the length limit.
WORST_BITS_PER_SYMBOL = MAX_CODE_LEN
# Stream bits consumed per LUT probe of the chunked direct-lookup decoder.
LUT_CHUNK_BITS = 8


# ---------------------------------------------------------------------------
# Codebook construction (host side, runs once per layer at prefill)
# ---------------------------------------------------------------------------


def _huffman_lengths(hist: np.ndarray) -> np.ndarray:
    """Code lengths from a histogram via the classic heap algorithm.

    Deterministic: ties broken by a monotone sequence id.  Symbols with zero
    count get length 0 (absent from the code).
    """
    hist = np.asarray(hist, dtype=np.int64)
    present = np.nonzero(hist > 0)[0]
    lengths = np.zeros(N_SYMBOLS, dtype=np.int32)
    if len(present) == 0:
        return lengths
    if len(present) == 1:
        lengths[present[0]] = 1
        return lengths
    # Heap of (count, uid, tree); tree is either a leaf symbol or (l, r).
    uid = 0
    heap: list[tuple[int, int, object]] = []
    for s in present:
        heap.append((int(hist[s]), uid, int(s)))
        uid += 1
    heapq.heapify(heap)
    while len(heap) > 1:
        c1, _, t1 = heapq.heappop(heap)
        c2, _, t2 = heapq.heappop(heap)
        heapq.heappush(heap, (c1 + c2, uid, (t1, t2)))
        uid += 1
    # Walk the tree to assign depths.
    stack = [(heap[0][2], 0)]
    while stack:
        node, depth = stack.pop()
        if isinstance(node, int):
            lengths[node] = max(depth, 1)
        else:
            l, r = node
            stack.append((l, depth + 1))
            stack.append((r, depth + 1))
    return lengths


def _flatten_histogram(hist: np.ndarray) -> np.ndarray:
    """Reduce skew so the longest Huffman code shortens (length limiting)."""
    h = np.asarray(hist, dtype=np.int64)
    out = np.where(h > 0, (h + 1) // 2, 0)
    return out


@dataclasses.dataclass(frozen=True)
class CodeBook:
    """Canonical Huffman codebook + array-based decode tree.

    Attributes
    ----------
    lengths : np.ndarray [256] int32 — code length per symbol (0 = absent).
    codes_msb : np.ndarray [256] uint32 — canonical codeword, MSB-first.
    codes_lsb : np.ndarray [256] uint32 — bit-reversed codeword (LSB-first
        emission order), what the encoder actually ORs into the stream.
    children : np.ndarray [n_nodes, 2] int32 — the paper's two-element child
        index array; the stream bit selects children[idx, bit].
    is_symbol : np.ndarray [n_nodes] int32 — 1 at leaves.
    symbols : np.ndarray [n_nodes] int32 — decoded symbol at leaves (0 else).
    """

    lengths: np.ndarray
    codes_msb: np.ndarray
    codes_lsb: np.ndarray
    children: np.ndarray
    is_symbol: np.ndarray
    symbols: np.ndarray

    @property
    def n_nodes(self) -> int:
        return int(self.children.shape[0])

    @property
    def serialized_bits(self) -> int:
        """Codebook transmission cost: 4 bits of length per symbol suffice
        for MAX_CODE_LEN=16 (canonical codes are reconstructible from
        lengths alone)."""
        return N_SYMBOLS * 4

    def expected_bits_per_symbol(self, hist: np.ndarray) -> float:
        h = np.asarray(hist, dtype=np.float64)
        tot = h.sum()
        if tot == 0:
            return 0.0
        return float((h * self.lengths).sum() / tot)

    def as_device_tables(self):
        """Decode tables as jnp arrays (padded to MAX_NODES for static shape)."""
        max_nodes = 2 * N_SYMBOLS
        ch = np.zeros((max_nodes, 2), np.int32)
        isym = np.zeros((max_nodes,), np.int32)
        sym = np.zeros((max_nodes,), np.int32)
        n = self.n_nodes
        ch[:n] = self.children
        isym[:n] = self.is_symbol
        sym[:n] = self.symbols
        return jnp.asarray(ch), jnp.asarray(isym), jnp.asarray(sym)

    def as_encode_tables(self):
        return jnp.asarray(self.codes_lsb), jnp.asarray(self.lengths.astype(np.uint32))

    @property
    def decode_probes(self) -> int:
        """LUT probes per symbol: one per started LUT_CHUNK_BITS of the
        longest codeword (≤ 2 under the MAX_CODE_LEN limit)."""
        return max(1, -(-int(self.lengths.max()) // LUT_CHUNK_BITS))

    def decode_lut(self) -> np.ndarray:
        """Flat ``[n_nodes * 256]`` i32 chunked-decode LUT (built once,
        cached on the instance — codebooks are frozen)."""
        lut = getattr(self, "_lut", None)
        if lut is None:
            lut = np.ascontiguousarray(build_decode_lut(self).reshape(-1))
            object.__setattr__(self, "_lut", lut)
        return lut


def _reverse_bits(code: int, length: int) -> int:
    out = 0
    for _ in range(length):
        out = (out << 1) | (code & 1)
        code >>= 1
    return out


def _build_tree(lengths: np.ndarray, codes_msb: np.ndarray):
    """Array-based tree: node 0 is the root; children[i] = [left, right]."""
    children = [[0, 0]]
    is_symbol = [0]
    symbols = [0]
    for s in range(N_SYMBOLS):
        L = int(lengths[s])
        if L == 0:
            continue
        code = int(codes_msb[s])
        idx = 0
        for b in range(L - 1, -1, -1):
            bit = (code >> b) & 1
            nxt = children[idx][bit]
            if nxt == 0:
                children.append([0, 0])
                is_symbol.append(0)
                symbols.append(0)
                nxt = len(children) - 1
                children[idx][bit] = nxt
            idx = nxt
        is_symbol[idx] = 1
        symbols[idx] = s
    return (
        np.asarray(children, np.int32),
        np.asarray(is_symbol, np.int32),
        np.asarray(symbols, np.int32),
    )


def build_codebook(hist) -> CodeBook:
    """Build a canonical, length-limited codebook from a histogram."""
    hist = np.asarray(hist, dtype=np.int64)
    if hist.shape != (N_SYMBOLS,):
        raise ValueError(f"hist must have shape ({N_SYMBOLS},), got {hist.shape}")
    work = hist.copy()
    lengths = _huffman_lengths(work)
    # Length-limit via histogram flattening (paper caps metadata at u16 bit
    # counts; 16-bit codes keep the worst case bounded and the tree shallow).
    for _ in range(64):
        if lengths.max() <= MAX_CODE_LEN:
            break
        work = _flatten_histogram(work)
        lengths = _huffman_lengths(work)
    assert lengths.max() <= MAX_CODE_LEN, "length limiting failed to converge"

    # Canonical code assignment: sort by (length, symbol).
    codes_msb = np.zeros(N_SYMBOLS, np.uint32)
    codes_lsb = np.zeros(N_SYMBOLS, np.uint32)
    order = sorted(s for s in range(N_SYMBOLS) if lengths[s] > 0)
    order.sort(key=lambda s: (lengths[s], s))
    code = 0
    prev_len = 0
    for s in order:
        L = int(lengths[s])
        code <<= L - prev_len
        codes_msb[s] = code
        codes_lsb[s] = _reverse_bits(code, L)
        code += 1
        prev_len = L
    children, is_symbol, symbols = _build_tree(lengths, codes_msb)
    return CodeBook(
        lengths=lengths,
        codes_msb=codes_msb,
        codes_lsb=codes_lsb,
        children=children,
        is_symbol=is_symbol,
        symbols=symbols,
    )


def build_decode_lut(book: CodeBook) -> np.ndarray:
    """Chunked-decode LUT ``[n_nodes, 256]`` i32 (host side, runs once).

    Entry ``[s, c]`` walks the ``LUT_CHUNK_BITS`` bits of chunk ``c``
    (LSB-first — stream bit order) down the array-based tree from node
    ``s`` and stops at the FIRST leaf:

        bits  0..7   symbol   (decoded symbol; 0 when no leaf was reached)
        bits  8..11  consumed (stream bits used, ≤ 8)
        bit   12     emit     (1 iff a leaf was reached inside the chunk)
        bits 16..    next     (continuation node: root after a leaf, else
                               the interior node after 8 bits)

    Because canonical codes are length-limited to ``MAX_CODE_LEN`` = 16,
    a symbol started at the root always completes within
    ``ceil(MAX_CODE_LEN / 8)`` = 2 probes (``CodeBook.decode_probes``
    tightens that to 1 when the fitted book's longest code is ≤ 8 bits).
    """
    n = book.n_nodes
    chunks = np.arange(1 << LUT_CHUNK_BITS, dtype=np.int32)
    idx = np.broadcast_to(np.arange(n, dtype=np.int32)[:, None],
                          (n, chunks.size)).copy()
    sym = np.zeros((n, chunks.size), np.int32)
    consumed = np.zeros((n, chunks.size), np.int32)
    emitted = np.zeros((n, chunks.size), bool)
    for b in range(LUT_CHUNK_BITS):
        bit = (chunks[None, :] >> b) & 1
        nxt = book.children[idx, bit]
        live = ~emitted
        consumed = np.where(live, consumed + 1, consumed)
        leaf = live & (book.is_symbol[nxt] == 1)
        sym = np.where(leaf, book.symbols[nxt], sym)
        idx = np.where(live, nxt, idx)
        emitted |= leaf
    nxt_state = np.where(emitted, 0, idx)  # reset-to-root at leaves
    return (sym | (consumed << 8) | (emitted.astype(np.int32) << 12)
            | (nxt_state << 16)).astype(np.int32)


def histogram(codes: Array) -> Array:
    """Device-side histogram of uint8 codes (paper builds this on GPU)."""
    return jnp.bincount(codes.reshape(-1).astype(jnp.int32), length=N_SYMBOLS)


# ---------------------------------------------------------------------------
# Numpy oracles (exact, used as the ground truth for every other impl)
# ---------------------------------------------------------------------------


def encode_block(codes: np.ndarray, book: CodeBook):
    """Encode a 2D block, one stream per row, tightly bit-packed in order.

    Returns (payload_words u32[...], nbits u16[S]).
    """
    codes = np.asarray(codes, np.uint8)
    S, L = codes.shape
    lengths = book.lengths
    nbits = lengths[codes.astype(np.int64)].sum(axis=1).astype(np.uint16)
    total = int(nbits.astype(np.int64).sum())
    words = np.zeros((total + 31) // 32 or 1, np.uint32)
    pos = 0
    for s in range(S):
        for j in range(L):
            sym = int(codes[s, j])
            cw = int(book.codes_lsb[sym])
            ln = int(lengths[sym])
            for b in range(ln):
                if (cw >> b) & 1:
                    words[(pos + b) >> 5] |= np.uint32(1 << ((pos + b) & 31))
            pos += ln
    return words, nbits


def decode_block(words: np.ndarray, nbits: np.ndarray, book: CodeBook, n_per_stream: int):
    """Branchless decode oracle — literal transcription of the paper's loop.

    Walks each stream's bit range with:
        idx       = children[idx, bit]
        out[w]    = symbols[idx]          (always written)
        w        += is_symbol[idx]        (advances only at leaves)
        idx      &= ~(-is_symbol[idx])    (reset-to-root without a branch)
    """
    words = np.asarray(words, np.uint32)
    nbits = np.asarray(nbits, np.int64)
    S = len(nbits)
    out = np.zeros((S, n_per_stream), np.uint8)
    starts = np.concatenate([[0], np.cumsum(nbits)])[:-1]
    for s in range(S):
        idx = 0
        w = 0
        buf = np.zeros(n_per_stream + 1, np.int64)  # +1 slack: last write lands at w==n
        for p in range(int(starts[s]), int(starts[s] + nbits[s])):
            bit = (int(words[p >> 5]) >> (p & 31)) & 1
            idx = int(book.children[idx, bit])
            isym = int(book.is_symbol[idx])
            buf[min(w, n_per_stream)] = book.symbols[idx] if isym else buf[min(w, n_per_stream)]
            w += isym
            idx &= ~(-isym)
        out[s] = buf[:n_per_stream]
    return out


# ---------------------------------------------------------------------------
# JAX implementations (jit-friendly; used inside the compression pipelines)
# ---------------------------------------------------------------------------


def encode_block_jax(codes: Array, codes_lsb: Array, lengths: Array, capacity_words: int):
    """Vectorized encoder. codes: [S, L] uint8.

    Every symbol's global bit offset is an exclusive cumsum of code lengths —
    the deterministic replacement for the paper's inclusive scan + atomic
    write-back index (DESIGN.md §2).  Each ≤16-bit codeword straddles at most
    two u32 words; both contributions are scatter-added (bitwise disjoint, so
    add ≡ or).

    Returns (payload u32[capacity_words], nbits u16[S], total_bits i32).
    """
    S, L = codes.shape
    flat = codes.reshape(-1).astype(jnp.int32)
    ln = lengths[flat].astype(jnp.uint32)  # [S*L]
    cw = codes_lsb[flat]  # [S*L] uint32, LSB-first
    ends = jnp.cumsum(ln.astype(jnp.int32))
    offs = ends - ln.astype(jnp.int32)  # exclusive cumsum
    total_bits = ends[-1]
    nbits = (
        ends.reshape(S, L)[:, -1] - jnp.concatenate([jnp.zeros(1, jnp.int32), ends.reshape(S, L)[:-1, -1]])
    ).astype(jnp.uint16)

    word_idx = offs >> 5
    bit_in = (offs & 31).astype(jnp.uint32)
    # Low contribution: bits of cw that fit in the current word.
    keep = jnp.uint32(32) - bit_in
    mask_low = jnp.where(keep >= 32, jnp.uint32(0xFFFFFFFF), (jnp.uint32(1) << keep) - 1)
    low = (cw & mask_low) << bit_in
    # High contribution: remaining bits spill into the next word.
    high = (cw >> (jnp.uint32(31) - bit_in)) >> 1  # == cw >> (32 - bit_in), safe at 0
    payload = jnp.zeros((capacity_words,), jnp.uint32)
    payload = payload.at[word_idx].add(low, mode="drop")
    payload = payload.at[word_idx + 1].add(high, mode="drop")
    return payload, nbits, total_bits


def walk_decode_jax(
    payload: Array,
    nbits: Array,
    children: Array,
    is_symbol: Array,
    symbols: Array,
    n_per_stream: int,
    max_bits: int,
) -> Array:
    """The branchless lockstep tree walk — kernel-safe shared core.

    One lane per stream; iteration p processes that stream's p-th bit with
    the paper's branchless updates (gather child, masked broadcast-write at
    the lane's output column, multiply-reset to root at leaves).  Lanes
    whose stream already ended are masked (is_symbol forced to 0), exactly
    as padding behaves on the GPU.  Only per-lane gathers and elementwise
    ops — the same function body runs inside the Pallas decode kernels
    (``repro.kernels.huffman_decode``) and, vmapped, as their jnp oracle.
    Returns float32 [S, n_per_stream].
    """
    S = nbits.shape[0]
    nbits_i = nbits.astype(jnp.int32)
    starts = jnp.cumsum(nbits_i) - nbits_i  # deterministic per-stream offsets
    col = jax.lax.broadcasted_iota(jnp.int32, (S, n_per_stream), 1)

    def body(p, carry):
        idx, w, out = carry
        gpos = starts + p  # [S]
        bit = (payload[gpos >> 5] >> (gpos & 31).astype(jnp.uint32)) & 1
        idx = children[idx, bit.astype(jnp.int32)]
        active = (p < nbits_i).astype(jnp.int32)
        isym = is_symbol[idx] * active
        sym = symbols[idx].astype(jnp.float32)
        # Masked broadcast-write: lane s writes column w[s] iff at a leaf.
        hit = (col == w[:, None]) & (isym[:, None] == 1)
        out = jnp.where(hit, sym[:, None], out)
        w = w + isym
        idx = idx * (1 - isym)  # branchless reset-to-root
        return idx, w, out

    idx0 = jnp.zeros((S,), jnp.int32)
    w0 = jnp.zeros((S,), jnp.int32)
    out0 = jnp.zeros((S, n_per_stream), jnp.float32)
    _, _, out = jax.lax.fori_loop(0, max_bits, body, (idx0, w0, out0))
    return out


def decode_block_jax(
    payload: Array,
    nbits: Array,
    children: Array,
    is_symbol: Array,
    symbols: Array,
    n_per_stream: int,
    max_stream_bits: int,
):
    """Vectorized branchless decode: every stream walks the tree in lockstep
    (``walk_decode_jax``).  Returns uint8 [S, n_per_stream]."""
    return walk_decode_jax(payload, nbits, children, is_symbol, symbols,
                           n_per_stream, max_stream_bits).astype(jnp.uint8)


def _peek_chunk(payload: Array, pos: Array, n_words: int) -> Array:
    """Extract LUT_CHUNK_BITS stream bits at bit position ``pos`` (LSB-first
    within little-endian u32 words; straddles at most two words).  Gathers
    clamp to the payload, so garbage walks past the end stay in bounds."""
    w = jnp.minimum(pos >> 5, n_words - 1)
    b = (pos & 31).astype(jnp.uint32)
    lo = payload[w] >> b
    # (x << (31 - b)) << 1 == x << (32 - b), well-defined at b == 0.
    hi = (payload[jnp.minimum(w + 1, n_words - 1)] << (jnp.uint32(31) - b)) << 1
    mask = jnp.uint32((1 << LUT_CHUNK_BITS) - 1)
    return ((lo | hi) & mask).astype(jnp.int32)


def decode_block_lut_jax(
    payload: Array,
    nbits: Array,
    lut: Array,
    n_per_stream: int,
    n_probes: int = 2,
):
    """Chunked direct-lookup decode (the production huffman Fetch path).

    Same contract as ``decode_block_jax`` but driven by the flat
    ``build_decode_lut`` table instead of the bit-serial walk: every stream
    decodes its j-th symbol in lockstep, one symbol per loop iteration,
    ``n_probes`` (= ``CodeBook.decode_probes``, ≤ 2) table probes each —
    instead of one tree step per BIT.  Symbols whose codeword would extend
    past the stream's ``nbits`` budget decode to 0, exactly as the walk's
    lane masking leaves padding/truncated streams — bit-identical outputs.
    Kernel-safe: per-lane gathers, elementwise selects, and a column
    ``dynamic_update_slice`` only; runs inside the fused Pallas attention
    kernel and vmapped in jnp.  Returns uint8 [S, n_per_stream].
    """
    S = nbits.shape[0]
    W = payload.shape[0]
    nbits_i = nbits.astype(jnp.int32)
    pos0 = jnp.cumsum(nbits_i) - nbits_i  # exclusive cumsum
    ends = pos0 + nbits_i  # first bit past each stream's budget

    def body(j, carry):
        pos, out = carry
        state = jnp.zeros((S,), jnp.int32)
        sym = jnp.zeros((S,), jnp.int32)
        done = jnp.zeros((S,), bool)
        for _ in range(n_probes):  # static ≤ 2 under MAX_CODE_LEN
            chunk = _peek_chunk(payload, pos, W)
            e = lut[state * (1 << LUT_CHUNK_BITS) + chunk]
            take = ~done
            emit = ((e >> 12) & 1) == 1
            sym = jnp.where(take & emit, e & 0xFF, sym)
            pos = jnp.where(take, pos + ((e >> 8) & 0xF), pos)
            state = jnp.where(take, e >> 16, state)
            done = done | emit
        # Budget mask: the symbol's last bit is pos - 1; a codeword that
        # runs past `ends` was never whole inside this stream (padding or
        # truncation) and the walk would not have emitted it.
        sym = jnp.where(pos <= ends, sym, 0)
        out = jax.lax.dynamic_update_slice(
            out, sym.astype(jnp.uint8)[:, None], (0, j))
        return pos, out

    out0 = jnp.zeros((S, n_per_stream), jnp.uint8)
    _, out = jax.lax.fori_loop(0, n_per_stream, body, (pos0, out0))
    return out
