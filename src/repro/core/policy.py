"""Compression policies: the paper's LLM-aware knobs as one declarative
object (DESIGN.md §7).

KVComp's quantizer is *LLM-aware*: K and V get different granularities and
error bounds, and follow-up work (PackKV) shows the right setting also varies
per layer.  ``CompressionPolicy`` captures that whole configuration space —
a base (layout, block_size, per-tensor rel_scale/bits) plus per-layer
overrides — and resolves it to per-layer ``CacheSpec``s that the model,
engine, and dry-run all consume.

Everything here is a frozen dataclass of scalars/tuples, so policies are
hashable and can ride in jit static args and pytree aux data.
"""

from __future__ import annotations

import dataclasses

from repro.core.cache import CacheSpec
from repro.core.layouts import get_layout

# The paper's Fig. 5 turning points — the single source for every default
# rel_scale (CompressionPolicy fields and the None-fallback in spec_for_layer).
DEFAULT_REL_SCALE_K = 0.05
DEFAULT_REL_SCALE_V = 0.15


@dataclasses.dataclass(frozen=True)
class TensorPolicy:
    """Per-tensor (K or V) quantizer knobs; ``None`` = inherit."""

    rel_scale: float | None = None
    bits: int | None = None

    def merged(self, base: "TensorPolicy") -> "TensorPolicy":
        return TensorPolicy(
            rel_scale=self.rel_scale if self.rel_scale is not None else base.rel_scale,
            bits=self.bits if self.bits is not None else base.bits,
        )


@dataclasses.dataclass(frozen=True)
class LayerOverride:
    """Overrides applied to an explicit set of attention-layer indices."""

    layers: tuple[int, ...]
    layout: str | None = None
    block_size: int | None = None
    k: TensorPolicy = TensorPolicy()
    v: TensorPolicy = TensorPolicy()
    attn_backend: str | None = None  # per-layer decode-attention backend
    span_tokens: int | None = None   # per-layer blockwise-scan span knob
    unroll_max: int | None = None    # per-layer blockwise-scan unroll knob


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Layout + quantizer configuration for a whole model's KV caches.

    ``attn_backend`` picks the decode-attention backend each layer's cache
    dispatches through (``repro.kernels.ops``): ``"auto"`` (fused on TPU for
    fused-capable layouts, blockwise-XLA elsewhere), ``"xla"``, ``"fused"``,
    or any ``register_backend``-ed name; overridable per layer.

    ``mode`` picks the storage container (DESIGN.md §10): ``"dense"``
    reserves a full per-row block ring per slot; ``"paged"`` stores blocks
    in one shared arena per layer addressed through per-row page tables, so
    the serving scheduler admits by memory pressure instead of slot count.
    The mode is whole-model (not per-layer overridable): every layer must
    flush the same logical block at the same step for one page id to serve
    all layers, which also means paged policies reject per-layer
    ``block_size`` overrides.
    """

    layout: str = "packed"
    block_size: int = 64
    k: TensorPolicy = TensorPolicy(rel_scale=DEFAULT_REL_SCALE_K)
    v: TensorPolicy = TensorPolicy(rel_scale=DEFAULT_REL_SCALE_V)
    kivi_bits: int = 2
    attn_backend: str = "auto"
    mode: str = "dense"  # "dense" | "paged" (repro.core.pool)
    # Blockwise-scan tuning knobs (None = env var / module default — see
    # ``repro.core.cache.blockwise_knobs``); per-layer overridable.
    span_tokens: int | None = None
    unroll_max: int | None = None
    overrides: tuple[LayerOverride, ...] = ()

    def __post_init__(self):
        get_layout(self.layout)  # fail fast on unknown names
        if self.mode not in ("dense", "paged"):
            raise ValueError(f"mode must be dense|paged, got {self.mode!r}")
        for ov in self.overrides:
            if ov.layout is not None:
                get_layout(ov.layout)
            if self.mode == "paged" and ov.block_size is not None:
                raise ValueError(
                    "paged mode needs a uniform block_size across layers "
                    "(one page id serves every layer's arena); drop the "
                    f"block_size override on layers {ov.layers}")

    @property
    def uniform(self) -> bool:
        """True when every layer resolves to the same spec (scan-friendly)."""
        return not self.overrides

    def resolve(self, layer: int) -> "CompressionPolicy":
        """Collapse overrides for one layer into an override-free policy."""
        layout, block, k, v = self.layout, self.block_size, self.k, self.v
        backend = self.attn_backend
        span, unroll = self.span_tokens, self.unroll_max
        for ov in self.overrides:
            if layer in ov.layers:
                layout = ov.layout if ov.layout is not None else layout
                block = ov.block_size if ov.block_size is not None else block
                k = ov.k.merged(k)
                v = ov.v.merged(v)
                backend = ov.attn_backend if ov.attn_backend is not None else backend
                span = ov.span_tokens if ov.span_tokens is not None else span
                unroll = ov.unroll_max if ov.unroll_max is not None else unroll
        return CompressionPolicy(layout=layout, block_size=block, k=k, v=v,
                                 kivi_bits=self.kivi_bits, attn_backend=backend,
                                 mode=self.mode, span_tokens=span,
                                 unroll_max=unroll)

    def spec_for_layer(self, layer: int, *, max_seq: int,
                       window: int | None = None,
                       pool_pages: int = 0) -> CacheSpec:
        """Resolve one layer's CacheSpec.

        ``pool_pages`` sizes the shared paged arena and is only known where
        a pool actually exists (the serving Server derives it from its byte
        budget and passes it through ``model.init_decode_state``).  A paged
        policy resolved WITHOUT a pool — solo admission prefills,
        ``api.compress``, the dry-run — gets the dense twin: those caches
        are private, full-ring, and are spliced into the arena page-by-page
        at admission (``pool.splice_row``).
        """
        r = self.resolve(layer)
        mode = r.mode if pool_pages > 0 else "dense"
        return CacheSpec(
            layout=r.layout,
            block_size=r.block_size,
            rel_scale_k=r.k.rel_scale if r.k.rel_scale is not None else DEFAULT_REL_SCALE_K,
            rel_scale_v=r.v.rel_scale if r.v.rel_scale is not None else DEFAULT_REL_SCALE_V,
            kivi_bits=r.kivi_bits,
            max_seq=max_seq,
            window=window,
            bits_k_override=r.k.bits,
            bits_v_override=r.v.bits,
            attn_backend=r.attn_backend,
            mode=mode,
            pool_pages=pool_pages if mode == "paged" else 0,
            span_tokens=r.span_tokens,
            unroll_max=r.unroll_max,
        )

    def layer_specs(self, n_layers: int, *, max_seq: int,
                    window: int | None = None,
                    pool_pages: int = 0) -> tuple[CacheSpec, ...]:
        return tuple(self.spec_for_layer(i, max_seq=max_seq, window=window,
                                         pool_pages=pool_pages)
                     for i in range(n_layers))
