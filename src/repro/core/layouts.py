"""Pluggable cache-layout strategies (DESIGN.md §4).

The paper's central claim is that the cache layout and the compression
algorithm are co-designed *and swappable per workload* (§4.2's KVCompCache
integration point).  This module makes that a first-class API: every way of
storing a layer's KV blocks is a ``CacheLayout`` registered by name, and the
cache manager (``repro.core.cache``), the fused kernels
(``repro.kernels.ops``), the serving engine, and the dry-run cost model all
dispatch through the registry instead of string-comparing layout names.

A layout owns four responsibilities:

* ``init_store``      — allocate the six store arrays of a ``LayerKVCache``
                        (payload + per-unit quantization scales).
* ``write_blocks``    — the Store stage: quantize + encode whole compression
                        blocks into slots of the block ring (prefill bulk
                        writes and decode-time buffer flushes share this).
* ``decode_block`` /
  ``tile_decode``     — the Fetch stage hot paths (DESIGN.md §9):
                        ``decode_block`` lazily decodes ONE block for the
                        blockwise XLA attention scan (the portable floor every
                        layout gets by default); ``tile_decode`` hands the
                        fused Pallas kernel a per-VMEM-tile decoder so
                        fused-eligible layouts (``supports_fused``) run the
                        in-situ ``q·(m + s∘c)`` kernel.  ``attend_block`` is
                        the single dispatch point between them (via the
                        backend registry in ``repro.kernels.ops``).
* ``fetch``           — bulk reconstruction of dequantized
                        ``[B, H, NB, T, D]`` K/V blocks — reconstruction,
                        tests, and the ``attend_materialized`` oracle only;
                        never on the decode hot path.
* ``size_report`` / ``bytes_per_token`` — exact and analytic size accounting
                        (metadata included), shared by the codec reports and
                        the roofline model.

Built-in layouts: ``raw`` (bf16, exact), ``packed`` (error-bounded quantizer
+ no-straddle bit-packing), ``kivi`` (fixed-bit baseline), and ``huffman``
(the paper's maximal-ratio path promoted to a servable layout: per-block
Huffman streams with u16 per-stream bit counts, decoded by the chunked
direct-lookup decoder — in VMEM inside the fused kernel and in the
blockwise XLA floor alike).  Register new ones with
``@register_layout("name")``.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, huffman
from repro.obs.profiling import annotate

Array = jax.Array

RAW_BITS_PER_VALUE = 16  # KV caches are bf16/fp16 at rest


def bits_for_rel_scale(rel_scale: float) -> int:
    """Static bit width that covers every code of an error-bounded quantizer:
    max code = round(1/rel_scale)."""
    return max(1, math.ceil(math.log2(round(1.0 / rel_scale) + 1)))


# ---------------------------------------------------------------------------
# Size accounting (paper §3.3.2 ~1/128 metadata analysis)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RatioReport:
    """Exact size accounting for one compressed tensor."""

    n_values: int
    payload_bits: int
    scale_bits: int
    stream_meta_bits: int
    offset_meta_bits: int
    codebook_bits: int

    @property
    def total_bits(self) -> int:
        return (
            self.payload_bits
            + self.scale_bits
            + self.stream_meta_bits
            + self.offset_meta_bits
            + self.codebook_bits
        )

    @property
    def ratio(self) -> float:
        return self.n_values * RAW_BITS_PER_VALUE / max(self.total_bits, 1)

    @property
    def bits_per_value(self) -> float:
        return self.total_bits / max(self.n_values, 1)


def raw_ratio(q) -> RatioReport:
    """Uncompressed baseline: 16 bits/value, no metadata."""
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=int(q.codes.size) * RAW_BITS_PER_VALUE,
        scale_bits=0,
        stream_meta_bits=0,
        offset_meta_bits=0,
        codebook_bits=0,
    )


def kivi_ratio(q, bits: int) -> RatioReport:
    """KIVI baseline: fixed b-bit payload + fp16 (min, step) per unit."""
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=int(q.codes.size) * bits,
        scale_bits=q.meta_bits,
        stream_meta_bits=0,
        offset_meta_bits=0,
        codebook_bits=0,
    )


def huffman_ratio(q, book: huffman.CodeBook, streams_shape: tuple[int, int]) -> RatioReport:
    """KVComp Huffman path sizes from the histogram (exact expected bits)."""
    hist = np.bincount(np.asarray(q.codes).reshape(-1), minlength=huffman.N_SYMBOLS)
    payload = int((hist * book.lengths).sum())
    n_streams = int(np.prod(q.codes.shape)) // streams_shape[1]
    n_blocks = max(n_streams // streams_shape[0], 1)
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=payload,
        scale_bits=q.meta_bits,
        stream_meta_bits=n_streams * 16,  # u16 bit count per stream (per-thread metadata)
        offset_meta_bits=n_blocks * 32,  # u32 offset per block (Block Offsets Array)
        codebook_bits=book.serialized_bits,
    )


def packed_ratio(q, block_codes: int) -> RatioReport:
    """TPU adaptive fixed-length path sizes."""
    codes = np.asarray(q.codes).reshape(-1, block_codes)
    mx = codes.max(axis=1).astype(np.int64)
    b = np.maximum(np.ceil(np.log2(mx + 1)), 1).astype(np.int64)
    payload = int((((block_codes * b) + 31) // 32 * 32).sum())
    n_blocks = codes.shape[0]
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=payload,
        scale_bits=q.meta_bits,
        stream_meta_bits=n_blocks * 8,  # u8 width per block
        offset_meta_bits=n_blocks * 32,
        codebook_bits=0,
    )


# ---------------------------------------------------------------------------
# Shared quantization primitive (paper §3.1.1)
# ---------------------------------------------------------------------------


def quant_block_minmax(x: Array, rel_scale: float, bits: int,
                       unit_axes: tuple[int, ...], kivi: bool):
    """Quantize one buffer block. x: [..., T, D] (f32). Returns codes u8 +
    (min, step) with unit axes reduced."""
    mn = jnp.min(x, axis=unit_axes, keepdims=True)
    mx = jnp.max(x, axis=unit_axes, keepdims=True)
    if kivi:
        step = (mx - mn) / (2**bits - 1)
    else:
        step = rel_scale * (mx - mn)
    safe = jnp.where(step > 0, step, 1.0)
    codes = jnp.clip(jnp.round((x - mn) / safe), 0, 2**bits - 1).astype(jnp.uint8)
    return codes, jnp.squeeze(mn, unit_axes), jnp.squeeze(step, unit_axes)


# ---------------------------------------------------------------------------
# The layout interface + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class FusedTileSpec:
    """Layout-owned decode hook for the fused Pallas attention kernel.

    The kernel (``repro.kernels.fused_kv_attn``) streams one store tile per
    grid step HBM→VMEM and calls ``decode_k``/``decode_v`` to reconstruct the
    dequantized ``[T, D]`` block in situ (DESIGN.md §9).  The decode callables
    must be kernel-safe (no captured host arrays, jnp ops only); they are also
    ``vmap``-ed over (B, H, NB) by the kernel's pure-jnp oracle, so one
    definition serves both paths.

    k_tile / v_tile : per-block store tile shape (what one grid step loads),
        e.g. ``(Wk,)`` packed words or ``(T, D)`` raw values.
    has_scales      : whether (min, step) arrays accompany the store; when
        False the decode callables receive ``None`` for both.
    decode_k(tile, mn, st, *aux) -> [T, D] f32 ; decode_v likewise (mn/st
        are the per-block BlockQuant/TokenQuant units).
    aux             : per-LAYER operands (small numpy arrays, identical for
        every tile) the kernel stages into VMEM alongside the tiles and
        appends to each decode call — e.g. the huffman layout's flat
        chunked-decode LUTs.  Block-invariant: their BlockSpec index maps
        are constant, the oracle closes over them un-vmapped.

    Instances must be cached per (layout, spec, head_dim) — they carry
    closures (and aux arrays), and jit statics hash them by IDENTITY
    (``eq=False``: ndarray fields forbid structural hashing), so a fresh
    instance per call would defeat every jit cache (see ``fused_tile_spec``).
    """

    k_tile: tuple[int, ...]
    v_tile: tuple[int, ...]
    has_scales: bool
    decode_k: object
    decode_v: object
    aux: tuple = ()


@functools.lru_cache(maxsize=256)
def fused_tile_spec(layout_name: str, spec, head_dim: int) -> FusedTileSpec | None:
    """Stable (memoized) tile spec so jit caches keyed on it don't retrace.

    ``supports_fused`` is authoritative: a layout that clears it gets None
    even if it inherits a ``_tile_decode`` from a fused-capable base (a
    custom layout subclassing packed with a different slot encoding — the
    packed unpacker would silently misread its slots).
    """
    lay = get_layout(layout_name)
    if not lay.supports_fused:
        return None
    return lay._tile_decode(spec, head_dim)


class _BlockView:
    """One-block slice of a cache's six store arrays (duck-typed cache).

    ``decode_block``'s generic fallback feeds this to ``decompress_k``/``_v``
    so any layout that can decompress its full store automatically gets the
    blockwise lazily-dequantized attention path.  Arrays without a block axis
    (e.g. the raw layout's dummy scales) pass through untouched.
    """

    def __init__(self, cache, n):
        for f in ("k_store", "k_min", "k_step", "v_store", "v_min", "v_step"):
            a = getattr(cache, f)
            if a.ndim >= 4:
                # The barrier keeps downstream per-block converts glued to
                # the slice: without it XLA rewrites convert(slice(x)) to
                # slice(convert(x)) and hoists a full-store f32 copy out of
                # the attention scan — exactly the materialization the
                # blockwise path exists to avoid.
                a = jax.lax.optimization_barrier(
                    jax.lax.dynamic_slice_in_dim(a, n, 1, 2))
            setattr(self, f, a)
        self.head_dim = cache.head_dim


def scatter_slots(store: Array, slots: Array, vals: Array) -> Array:
    """Write per-row block payloads into block slots of a store array.

    store : [B, H, NB, ...] (dense ring) or [1, H, P, ...] (a paged arena
    shared by every row — DESIGN.md §10); slots : i32 [B, n] *physical*
    block indices (out-of-range = drop sentinel — that row writes nothing;
    paged callers translate logical ring slots through the page table
    first, see ``pool.lookup_slots``); vals : [B, H, n, ...].  Rows of a
    continuous batch flush at different times, so every row addresses its
    own slot.  Arena writes rely on the pool's no-alias invariant: live
    rows never share a page, so the scatter is collision-free.
    """
    B = slots.shape[0]
    if store.shape[0] == 1 and B > 1:
        # Shared arena: every row's blocks land in its own pages of the one
        # store.  (B == 1 degenerates to the dense branch, which writes
        # store[0, :, slot] — the identical arena update.)
        flat = slots.reshape(-1)  # [B*n]
        upd = jnp.moveaxis(vals, 1, 0).reshape(
            vals.shape[1], -1, *vals.shape[3:])  # [H, B*n, ...]
        return store[0].at[:, flat].set(upd, mode="drop")[None]
    bidx = jnp.arange(B)[:, None]  # broadcasts against slots [B, n]
    # Advanced indices at axes (0, 2) are separated by the H slice, so the
    # indexed dims move to the front: the update value is [B, n, H, ...].
    return store.at[bidx, :, slots].set(jnp.moveaxis(vals, 2, 1), mode="drop")


class CacheLayout:
    """Strategy interface for one way of storing a layer's KV blocks.

    Implementations are stateless singletons; every method receives the
    static ``CacheSpec`` (hashable, lives in the pytree aux) and operates on
    the six store arrays of a ``LayerKVCache`` (duck-typed — this module
    never imports the cache container, so registration stays cycle-free).
    """

    name: str = "?"
    # Eligible for the fused Pallas decode kernel (uniform no-straddle words).
    supports_fused: bool = False
    # size_report needs a fitted huffman.CodeBook passed via ``book=``.
    needs_codebook: bool = False
    # Offline quantizer family: fixed-bit (KIVI) vs error-bounded steps.
    kivi_step: bool = False

    # -- static properties ----------------------------------------------------
    def bits_k(self, spec) -> int:
        raise NotImplementedError

    def bits_v(self, spec) -> int:
        raise NotImplementedError

    # -- cache storage --------------------------------------------------------
    def init_store(self, spec, batch: int, n_kv_heads: int, head_dim: int, dtype):
        """Allocate (k_store, k_min, k_step, v_store, v_min, v_step)."""
        raise NotImplementedError

    def write_blocks(self, spec, cache, slots: Array, kb: Array, vb: Array):
        """Store stage: write raw blocks kb/vb [B, H, n, T, D] into per-row
        ring slots [B, n] (out-of-range slot = drop sentinel for that row).
        Returns the six updated store arrays."""
        raise NotImplementedError

    def fetch(self, spec, cache):
        """Bulk Fetch: dequantized K and V [B, H, NB, T, D].

        Materializes the whole store — reconstruction/tests/benchmarks and
        the ``attend_materialized`` oracle only.  The decode hot path never
        calls this; it goes through ``decode_block`` (blockwise XLA scan) or
        ``tile_decode`` (fused Pallas kernel) instead.
        """
        return self.decompress_k(spec, cache), self.decompress_v(spec, cache)

    def decompress_k(self, spec, cache) -> Array:
        raise NotImplementedError

    def decompress_v(self, spec, cache) -> Array:
        raise NotImplementedError

    # -- decode attention hooks ----------------------------------------------
    def decode_block(self, spec, cache, n):
        """Lazily decode ONE store block for the blockwise attention scan.

        Returns ``(k_codes, k_mn, k_st, v_codes, v_mn, v_st)`` with
        ``k_codes``/``v_codes`` f32 ``[B, H, T, D]`` and per-block quant units
        ``k_mn``/``k_st`` ``[B, H, D]``, ``v_mn``/``v_st`` ``[B, H, T]``, under
        the dequantization convention ``x = mn + codes ∘ st``.  ``mn``/``st``
        of ``None`` mean the codes already ARE the dequantized values — the
        scan then skips the ``q·mn + (q∘st)·c`` fusion and dots directly.

        The generic fallback decompresses a one-block view, so any registered
        layout gets the blockwise path for free; quantizing layouts override
        it to return raw codes + scales and keep dequantization folded into
        the attention matvec.
        """
        view = _BlockView(cache, n)
        kd = self.decompress_k(spec, view)[:, :, 0].astype(jnp.float32)
        vd = self.decompress_v(spec, view)[:, :, 0].astype(jnp.float32)
        return kd, None, None, vd, None, None

    def decode_span(self, spec, cache, start, count: int):
        """Lazily decode ``count`` contiguous blocks ``[start, start+count)``
        for one step of the blockwise attention scan.

        Same contract as ``decode_block`` with a block axis C inserted:
        codes f32 ``[B, H, C, T, D]``, units ``[B, H, C, D]`` / ``[B, H, C, T]``
        (or ``None``).  The default stacks ``decode_block`` results; layouts
        whose store slices contiguously override it so one step decodes in
        ONE vectorized op instead of C small ones.
        """
        blocks = [self.decode_block(spec, cache, start + c) for c in range(count)]
        stk = lambda i: (None if blocks[0][i] is None
                         else jnp.stack([b[i] for b in blocks], axis=2))
        return tuple(stk(i) for i in range(6))

    def tile_decode(self, spec, head_dim: int) -> FusedTileSpec | None:
        """The fused Pallas kernel's per-tile decode hook (memoized).

        ``None`` means the layout cannot run in the fused kernel (no
        fixed-size tile formulation of its decode) and it falls back to
        the blockwise XLA scan.  ``supports_fused`` mirrors this statically.
        """
        return fused_tile_spec(self.name, spec, head_dim)

    def _tile_decode(self, spec, head_dim: int) -> FusedTileSpec | None:
        return None

    def attend_block(self, cache, q: Array, scale: float | None = None,
                     backend: str | None = None) -> Array:
        """Decode attention over (store ∥ buffer) — THE dispatch point.

        Routes through the attention-backend registry in
        ``repro.kernels.ops``: ``fused`` runs the Pallas in-situ-decompression
        kernel via ``tile_decode``; ``xla`` runs the blockwise
        lazily-dequantized scan via ``decode_block``.  ``backend=None``
        defers to the cache spec's ``attn_backend`` (default ``"auto"``)."""
        from repro.kernels import ops  # late: kernels import core

        return ops.decode_attention(cache, q, scale, backend=backend)

    # -- size accounting ------------------------------------------------------
    def size_report(self, q, *, block_size: int, head_dim: int,
                    kivi_bits: int = 2, book: huffman.CodeBook | None = None) -> RatioReport:
        """Exact accounting for a quantized tensor stored under this layout."""
        raise NotImplementedError

    def bytes_per_token(self, spec, n_kv_heads: int, head_dim: int) -> float:
        """Analytic HBM bytes per cached token per layer (payload + scales);
        feeds the dry-run roofline model."""
        raise NotImplementedError


_REGISTRY: dict[str, CacheLayout] = {}


def register_layout(name: str):
    """Class decorator: instantiate and register a layout under ``name``."""

    def deco(cls):
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls

    return deco


def get_layout(name: str) -> CacheLayout:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown cache layout {name!r}; available: {available_layouts()}"
        ) from None


def available_layouts() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# raw: bf16 blocks, no compression (the exactness baseline)
# ---------------------------------------------------------------------------


@register_layout("raw")
class RawLayout(CacheLayout):
    supports_fused = True  # passthrough tile decoder (see _tile_decode)

    def bits_k(self, spec) -> int:
        return RAW_BITS_PER_VALUE

    def bits_v(self, spec) -> int:
        return RAW_BITS_PER_VALUE

    def init_store(self, spec, batch, n_kv_heads, head_dim, dtype):
        B, H, T, D, NB = batch, n_kv_heads, spec.block_size, head_dim, spec.store_blocks
        k_store = jnp.zeros((B, H, NB, T, D), dtype)
        v_store = jnp.zeros((B, H, NB, T, D), dtype)
        dummy = jnp.zeros((1,), dtype)
        return k_store, dummy, dummy, v_store, dummy, dummy

    def write_blocks(self, spec, cache, slots, kb, vb):
        dt = cache.k_store.dtype
        k_store = scatter_slots(cache.k_store, slots, kb.astype(dt))
        v_store = scatter_slots(cache.v_store, slots, vb.astype(dt))
        return (k_store, cache.k_min, cache.k_step,
                v_store, cache.v_min, cache.v_step)

    def decompress_k(self, spec, cache):
        return cache.k_store

    def decompress_v(self, spec, cache):
        return cache.v_store

    def decode_span(self, spec, cache, start, count: int):
        # Values with no scales; the barrier keeps XLA from commuting the
        # downstream f32 convert above the slice and hoisting a full-store
        # copy out of the attention scan (see _BlockView).
        sl = lambda a: jax.lax.optimization_barrier(
            jax.lax.dynamic_slice_in_dim(a, start, count, 2))
        return sl(cache.k_store), None, None, sl(cache.v_store), None, None

    def _tile_decode(self, spec, head_dim):
        # Passthrough decoder: the raw layout rides the same fused kernel as
        # the quantized layouts (one uniform decode path, not a special
        # case); a tile is the [T, D] bf16 block itself, no scales.
        dec = lambda tile, mn, st: tile.astype(jnp.float32)
        shape = (spec.block_size, head_dim)
        return FusedTileSpec(k_tile=shape, v_tile=shape, has_scales=False,
                             decode_k=dec, decode_v=dec)

    def size_report(self, q, *, block_size, head_dim, kivi_bits=2, book=None):
        return raw_ratio(q)

    def bytes_per_token(self, spec, n_kv_heads, head_dim):
        return 2.0 * n_kv_heads * head_dim * 2  # K+V bf16


# ---------------------------------------------------------------------------
# packed: error-bounded quantizer + no-straddle bit-packing (the TPU path)
# ---------------------------------------------------------------------------


@register_layout("packed")
class PackedLayout(CacheLayout):
    supports_fused = True

    def bits_k(self, spec) -> int:
        return bits_for_rel_scale(spec.rel_scale_k)

    def bits_v(self, spec) -> int:
        return bits_for_rel_scale(spec.rel_scale_v)

    def init_store(self, spec, batch, n_kv_heads, head_dim, dtype):
        B, H, T, D, NB = batch, n_kv_heads, spec.block_size, head_dim, spec.store_blocks
        k_store = jnp.zeros((B, H, NB, spec.words_k(D)), jnp.uint32)
        v_store = jnp.zeros((B, H, NB, spec.words_v(D)), jnp.uint32)
        k_min = jnp.zeros((B, H, NB, D), dtype)
        k_step = jnp.zeros((B, H, NB, D), dtype)
        v_min = jnp.zeros((B, H, NB, T), dtype)
        v_step = jnp.zeros((B, H, NB, T), dtype)
        return k_store, k_min, k_step, v_store, v_min, v_step

    def quantize_blocks(self, spec, k: Array, v: Array):
        """Shared lossy stage for every quantizing layout: [B, H, NB, T, D]
        raw blocks -> (codes u8, min, step) per tensor.  K: BlockQuant —
        min/max over the block's T tokens, per channel; V: TokenQuant —
        min/max over D, per token."""
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        k_codes, k_mn, k_st = quant_block_minmax(
            kf, spec.rel_scale_k, spec.bits_k, (-2,), self.kivi_step)
        v_codes, v_mn, v_st = quant_block_minmax(
            vf, spec.rel_scale_v, spec.bits_v, (-1,), self.kivi_step)
        return k_codes, k_mn, k_st, v_codes, v_mn, v_st

    def compress_blocks(self, spec, k: Array, v: Array):
        """Compress [B, H, NB, T, D] raw blocks -> packed stores + scales."""
        k_codes, k_mn, k_st, v_codes, v_mn, v_st = self.quantize_blocks(spec, k, v)
        B, H, NB, T, D = k.shape
        k_store = bitpack.pack_nostraddle(k_codes.reshape(B, H, NB, T * D), spec.bits_k)
        v_store = bitpack.pack_nostraddle(v_codes.reshape(B, H, NB, T * D), spec.bits_v)
        dt = jnp.bfloat16
        return (k_store, k_mn.astype(dt), k_st.astype(dt),
                v_store, v_mn.astype(dt), v_st.astype(dt))

    def write_blocks(self, spec, cache, slots, kb, vb):
        ks, kmn, kst, vs, vmn, vst = self.compress_blocks(spec, kb, vb)
        return (
            scatter_slots(cache.k_store, slots, ks),
            scatter_slots(cache.k_min, slots, kmn),
            scatter_slots(cache.k_step, slots, kst),
            scatter_slots(cache.v_store, slots, vs),
            scatter_slots(cache.v_min, slots, vmn),
            scatter_slots(cache.v_step, slots, vst),
        )

    def decompress_k(self, spec, cache):
        B, H, NB, _ = cache.k_store.shape
        T, D = spec.block_size, cache.head_dim
        codes = bitpack.unpack_nostraddle(
            cache.k_store, spec.bits_k, T * D).reshape(B, H, NB, T, D)
        return (cache.k_min[:, :, :, None, :].astype(jnp.float32)
                + codes.astype(jnp.float32)
                * cache.k_step[:, :, :, None, :].astype(jnp.float32)
                ).astype(jnp.bfloat16)

    def decompress_v(self, spec, cache):
        B, H, NB, _ = cache.v_store.shape
        T, D = spec.block_size, cache.head_dim
        codes = bitpack.unpack_nostraddle(
            cache.v_store, spec.bits_v, T * D).reshape(B, H, NB, T, D)
        return (cache.v_min[:, :, :, :, None].astype(jnp.float32)
                + codes.astype(jnp.float32)
                * cache.v_step[:, :, :, :, None].astype(jnp.float32)
                ).astype(jnp.bfloat16)

    def decode_block(self, spec, cache, n):
        # Raw codes + scales: dequantization stays folded into the attention
        # matvec via q·(mn + st∘c) = q·mn + (q∘st)·c (paper §3.3.2).
        out = self.decode_span(spec, cache, n, 1)
        return tuple(a[:, :, 0] for a in out)

    def decode_span(self, spec, cache, start, count: int):
        # One contiguous slice + one vectorized no-straddle unpack per tensor.
        B, H = cache.k_store.shape[:2]
        T, D = spec.block_size, cache.head_dim
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, count, 2)
        kc = bitpack.unpack_nostraddle(sl(cache.k_store), spec.bits_k, T * D)
        vc = bitpack.unpack_nostraddle(sl(cache.v_store), spec.bits_v, T * D)
        return (kc.reshape(B, H, count, T, D).astype(jnp.float32),
                sl(cache.k_min), sl(cache.k_step),
                vc.reshape(B, H, count, T, D).astype(jnp.float32),
                sl(cache.v_min), sl(cache.v_step))

    def _tile_decode(self, spec, head_dim):
        T, D = spec.block_size, head_dim
        bits_k, bits_v = spec.bits_k, spec.bits_v

        def dk(tile, mn, st):
            codes = bitpack.unpack_nostraddle_tile(
                tile, bits_k, T * D).reshape(T, D).astype(jnp.float32)
            return (mn.astype(jnp.float32)[None, :]
                    + codes * st.astype(jnp.float32)[None, :])

        def dv(tile, mn, st):
            codes = bitpack.unpack_nostraddle_tile(
                tile, bits_v, T * D).reshape(T, D).astype(jnp.float32)
            return (mn.astype(jnp.float32)[:, None]
                    + codes * st.astype(jnp.float32)[:, None])

        return FusedTileSpec(k_tile=(spec.words_k(D),), v_tile=(spec.words_v(D),),
                             has_scales=True, decode_k=dk, decode_v=dv)

    def size_report(self, q, *, block_size, head_dim, kivi_bits=2, book=None):
        return packed_ratio(q, block_size * head_dim)

    def bytes_per_token(self, spec, n_kv_heads, head_dim):
        payload = n_kv_heads * head_dim * (spec.bits_k + spec.bits_v) / 8
        # scales: K per (block, channel) 2x bf16; V per token 2x bf16
        meta = n_kv_heads * (2 * head_dim * 2 * 2 / spec.block_size + 2 * 2)
        return payload + meta


# ---------------------------------------------------------------------------
# kivi: fixed-bit asymmetric baseline (paper §4.1)
# ---------------------------------------------------------------------------


@register_layout("kivi")
class KiviLayout(PackedLayout):
    kivi_step = True  # step = (max−min)/(2^b − 1)

    def bits_k(self, spec) -> int:
        return spec.kivi_bits

    def bits_v(self, spec) -> int:
        return spec.kivi_bits

    def size_report(self, q, *, block_size, head_dim, kivi_bits=2, book=None):
        return kivi_ratio(q, kivi_bits)


# ---------------------------------------------------------------------------
# huffman: the paper's maximal-ratio path as a servable layout
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def default_codebook(n_codes: int) -> huffman.CodeBook:
    """Static prior codebook covering codes [0, n_codes).

    A servable layout needs a codebook available at trace time with static
    shapes, so the layout ships a deterministic prior (triangular, peaked at
    the code range's center — the error-bounded quantizer's codes of
    LLM-like data are bell-shaped, paper Fig. 3) instead of the offline
    codec's per-layer fitted histograms.  Coverage of the full code range
    guarantees losslessness for any input; fitted codebooks remain available
    through ``repro.core.codec.KVCompCodec`` (DESIGN.md §4).
    """
    hist = np.zeros(huffman.N_SYMBOLS, np.int64)
    c = np.arange(n_codes, dtype=np.float64)
    center = (n_codes - 1) / 2.0
    hist[:n_codes] = 1 + np.round(1000.0 * (n_codes - np.abs(c - center))).astype(np.int64)
    return huffman.build_codebook(hist)


def _pack_u16_pairs(nbits: Array) -> Array:
    """u16 [S] per-stream bit counts -> u32 [(S+1)//2] header words."""
    S = nbits.shape[0]
    nb = nbits.astype(jnp.uint32)
    if S % 2:
        nb = jnp.concatenate([nb, jnp.zeros((1,), jnp.uint32)])
    return nb[0::2] | (nb[1::2] << 16)


def _unpack_u16_pairs(hdr: Array, S: int) -> Array:
    lo = hdr & jnp.uint32(0xFFFF)
    hi = hdr >> 16
    return jnp.stack([lo, hi], axis=-1).reshape(-1)[:S].astype(jnp.uint16)


@register_layout("huffman")
class HuffmanLayout(PackedLayout):
    """Huffman-coded blocks behind the same six-array cache contract.

    Slot layout (per compression block, K and V alike): ``(T+1)//2`` header
    words holding the T per-stream u16 bit counts (one stream per token, D
    symbols each — the paper's per-thread metadata), followed by a
    worst-case-sized payload region (``T·D·max_code_len`` bits under the
    static prior codebook).  Quantization scales are stored exactly as in
    the packed layout, so ``q·(m + s∘c)`` algebra still applies after the
    entropy decode.  Allocated capacity is worst-case; ``size_report``
    accounts the *actual* entropy-coded bits (DESIGN.md §4).

    The payload is ragged INSIDE the slot, but the slot itself is a fixed
    worst-case-padded tile — so the fused Pallas kernel streams whole slots
    HBM→VMEM like any other layout and ``tile_decode`` re-derives the
    per-stream offsets from the header in VMEM (``supports_fused``).  Both
    the in-kernel decode and the blockwise XLA floor run the chunked
    direct-lookup decoder (``huffman.decode_block_lut_jax``): ≤ 2 LUT
    probes per symbol instead of one tree step per bit, with the canonical
    codebooks' flat LUTs riding along as the tile spec's per-layer ``aux``
    operands (DESIGN.md §9).
    """

    supports_fused = True  # fixed-size slot tiles; offsets decoded in VMEM
    needs_codebook = True

    # -- codebooks (static prior; see default_codebook) ----------------------
    def _n_codes(self, spec, bits: int, rel_scale: float) -> int:
        n = round(1.0 / rel_scale) + 1
        return int(min(n, 2**bits, huffman.N_SYMBOLS))

    def book_k(self, spec) -> huffman.CodeBook:
        return default_codebook(self._n_codes(spec, spec.bits_k, spec.rel_scale_k))

    def book_v(self, spec) -> huffman.CodeBook:
        return default_codebook(self._n_codes(spec, spec.bits_v, spec.rel_scale_v))

    def _slot_words(self, spec, head_dim: int, book: huffman.CodeBook) -> tuple[int, int]:
        """(header_words, payload_words) for one block's slot."""
        T = spec.block_size
        maxlen = int(book.lengths.max())
        hdr = (T + 1) // 2
        payload = (T * head_dim * maxlen + 31) // 32 + 1
        return hdr, payload

    def init_store(self, spec, batch, n_kv_heads, head_dim, dtype):
        B, H, T, D, NB = batch, n_kv_heads, spec.block_size, head_dim, spec.store_blocks
        hk, pk = self._slot_words(spec, D, self.book_k(spec))
        hv, pv = self._slot_words(spec, D, self.book_v(spec))
        k_store = jnp.zeros((B, H, NB, hk + pk), jnp.uint32)
        v_store = jnp.zeros((B, H, NB, hv + pv), jnp.uint32)
        k_min = jnp.zeros((B, H, NB, D), dtype)
        k_step = jnp.zeros((B, H, NB, D), dtype)
        v_min = jnp.zeros((B, H, NB, T), dtype)
        v_step = jnp.zeros((B, H, NB, T), dtype)
        return k_store, k_min, k_step, v_store, v_min, v_step

    def _encode(self, spec, codes: Array, book: huffman.CodeBook) -> Array:
        """codes u8 [B, H, n, T, D] -> slots u32 [B, H, n, hdr+payload]."""
        B, H, n, T, D = codes.shape
        hdr_w, pay_w = self._slot_words(spec, D, book)
        cl, ln = book.as_encode_tables()

        def enc(blk):  # [T, D]
            payload, nbits, _ = huffman.encode_block_jax(blk, cl, ln, pay_w)
            return jnp.concatenate([_pack_u16_pairs(nbits), payload])

        slots = jax.vmap(enc)(codes.reshape(B * H * n, T, D))
        return slots.reshape(B, H, n, hdr_w + pay_w)

    def _decode(self, spec, store: Array, head_dim: int, book: huffman.CodeBook) -> Array:
        """slots u32 [B, H, NB, W] -> codes u8 [B, H, NB, T, D].

        Chunked LUT decode (≤ 2 probes per symbol) — the same decoder the
        fused kernel runs per tile, here vmapped over every slot for the
        blockwise XLA floor.
        """
        B, H, NB, _ = store.shape
        T, D = spec.block_size, head_dim
        hdr_w, _ = self._slot_words(spec, D, book)
        lut = jnp.asarray(book.decode_lut())
        probes = book.decode_probes

        def dec(slot):  # [hdr+payload]
            nbits = _unpack_u16_pairs(slot[:hdr_w], T)
            return huffman.decode_block_lut_jax(slot[hdr_w:], nbits, lut, D, probes)

        with annotate("huffman_lut_decode"):
            codes = jax.vmap(dec)(store.reshape(B * H * NB, -1))
        return codes.reshape(B, H, NB, T, D)

    def write_blocks(self, spec, cache, slots, kb, vb):
        k_codes, k_mn, k_st, v_codes, v_mn, v_st = self.quantize_blocks(spec, kb, vb)
        ks = self._encode(spec, k_codes, self.book_k(spec))
        vs = self._encode(spec, v_codes, self.book_v(spec))
        dt = jnp.bfloat16
        return (
            scatter_slots(cache.k_store, slots, ks),
            scatter_slots(cache.k_min, slots, k_mn.astype(dt)),
            scatter_slots(cache.k_step, slots, k_st.astype(dt)),
            scatter_slots(cache.v_store, slots, vs),
            scatter_slots(cache.v_min, slots, v_mn.astype(dt)),
            scatter_slots(cache.v_step, slots, v_st.astype(dt)),
        )

    def decompress_k(self, spec, cache):
        codes = self._decode(spec, cache.k_store, cache.head_dim, self.book_k(spec))
        return (cache.k_min[:, :, :, None, :].astype(jnp.float32)
                + codes.astype(jnp.float32)
                * cache.k_step[:, :, :, None, :].astype(jnp.float32)
                ).astype(jnp.bfloat16)

    def decompress_v(self, spec, cache):
        codes = self._decode(spec, cache.v_store, cache.head_dim, self.book_v(spec))
        return (cache.v_min[:, :, :, :, None].astype(jnp.float32)
                + codes.astype(jnp.float32)
                * cache.v_step[:, :, :, :, None].astype(jnp.float32)
                ).astype(jnp.bfloat16)

    def decode_block(self, spec, cache, n):
        out = self.decode_span(spec, cache, n, 1)
        return tuple(a[:, :, 0] for a in out)

    def decode_span(self, spec, cache, start, count: int):
        # LUT decode of one SPAN of blocks per scan step (the vmapped
        # decoder batches over B·H·count slots) — the blockwise path never
        # reconstructs the whole [B, H, NB, T, D] store.  Codes are
        # bit-identical to the packed layout's, so the downstream fused
        # matvec algebra is shared unchanged.
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, count, 2)
        kc = self._decode(spec, sl(cache.k_store), cache.head_dim,
                          self.book_k(spec))
        vc = self._decode(spec, sl(cache.v_store), cache.head_dim,
                          self.book_v(spec))
        return (kc.astype(jnp.float32), sl(cache.k_min), sl(cache.k_step),
                vc.astype(jnp.float32), sl(cache.v_min), sl(cache.v_step))

    def _tile_decode(self, spec, head_dim):
        # One tile = one whole worst-case-padded slot (header ∥ payload);
        # the ragged per-stream offsets are re-derived from the u16 header
        # INSIDE the kernel, and the canonical codebooks' flat LUTs ride as
        # per-layer aux operands the kernel stages into VMEM (DESIGN.md §9).
        T, D = spec.block_size, head_dim
        book_k, book_v = self.book_k(spec), self.book_v(spec)
        hk, pk = self._slot_words(spec, D, book_k)
        hv, pv = self._slot_words(spec, D, book_v)
        probes_k, probes_v = book_k.decode_probes, book_v.decode_probes
        f32 = jnp.float32

        def dk(tile, mn, st, lut_k, lut_v):
            nbits = _unpack_u16_pairs(tile[:hk], T)
            codes = huffman.decode_block_lut_jax(
                tile[hk:], nbits, lut_k, D, probes_k).astype(f32)  # [T, D]
            return mn.astype(f32)[None, :] + codes * st.astype(f32)[None, :]

        def dv(tile, mn, st, lut_k, lut_v):
            nbits = _unpack_u16_pairs(tile[:hv], T)
            codes = huffman.decode_block_lut_jax(
                tile[hv:], nbits, lut_v, D, probes_v).astype(f32)
            return mn.astype(f32)[:, None] + codes * st.astype(f32)[:, None]

        return FusedTileSpec(k_tile=(hk + pk,), v_tile=(hv + pv,),
                             has_scales=True, decode_k=dk, decode_v=dv,
                             aux=(book_k.decode_lut(), book_v.decode_lut()))

    def size_report(self, q, *, block_size, head_dim, kivi_bits=2, book=None):
        assert book is not None, "huffman size_report needs a fitted codebook"
        return huffman_ratio(q, book, (block_size, head_dim))

    def bytes_per_token(self, spec, n_kv_heads, head_dim):
        # Allocated (worst-case slot) bytes — what HBM actually holds; the
        # entropy win shows up in size_report's expected-bits accounting.
        T = spec.block_size
        hk, pk = self._slot_words(spec, head_dim, self.book_k(spec))
        hv, pv = self._slot_words(spec, head_dim, self.book_v(spec))
        payload = n_kv_heads * 4.0 * (hk + pk + hv + pv) / T
        meta = n_kv_heads * (2 * head_dim * 2 * 2 / T + 2 * 2)
        return payload + meta
