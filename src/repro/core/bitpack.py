"""Adaptive fixed-length bit-packing — the TPU-native entropy path.

DESIGN.md §2: symbol-serial Huffman decode does not vectorize on a TPU VPU,
so the performance path preserves the paper's entropy adaptivity at *block*
granularity instead of *symbol* granularity: each 2D block stores its codes
with ``b = ceil(log2(max_code + 1))`` bits.  Because the quantized KV code
histogram is tightly concentrated (paper Fig. 3), most blocks need only a few
bits, and unpacking is pure shift/mask — fully vectorizable and fusable with
the attention matvec.

Layouts
-------
* ``pack_bits`` / ``unpack_bits`` — static bit-width b ∈ [1, 8]; codes are
  packed LSB-first into little-endian u32 words along the last axis.  Static
  shapes; straddling words is handled (b need not divide 32).
* ``choose_bits`` — per-block adaptive width (pow2-rounded option for the
  Pallas kernel's lax.switch dispatch).
* ``pack_adaptive`` / ``unpack_adaptive`` — ragged multi-block container with
  deterministic cumsum offsets (the atomic-free Block Offsets Array).

All functions are jnp and jit-safe unless suffixed ``_np``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def packed_words(n_codes: int, bits: int) -> int:
    """Number of u32 words to hold n_codes values at `bits` bits each."""
    return (n_codes * bits + 31) // 32


def pack_bits(codes: Array, bits: int) -> Array:
    """Pack uint8 codes (< 2**bits) along the last axis into u32 words.

    codes: [..., L]  ->  [..., packed_words(L, bits)] uint32.
    Works for any static 1 <= bits <= 8 (values straddling a word boundary
    contribute to two words; contributions are bitwise disjoint so
    scatter-add ≡ or).
    """
    if not (1 <= bits <= 8):
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    *lead, L = codes.shape
    W = packed_words(L, bits)
    c = codes.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    j = np.arange(L)
    word_idx = jnp.asarray((j * bits) >> 5)
    bit_in = jnp.asarray((j * bits) & 31, dtype=np.uint32)
    keep = jnp.uint32(32) - bit_in
    mask_low = jnp.where(keep >= 32, jnp.uint32(0xFFFFFFFF), (jnp.uint32(1) << keep) - 1)
    low = (c & mask_low) << bit_in
    high = (c >> (jnp.uint32(31) - bit_in)) >> 1
    flat = c.reshape(-1, L)
    out = jnp.zeros((flat.shape[0], W), jnp.uint32)
    low = low.reshape(-1, L)
    high = high.reshape(-1, L)
    rows = jnp.arange(flat.shape[0])[:, None]
    out = out.at[rows, word_idx[None, :]].add(low, mode="drop")
    out = out.at[rows, word_idx[None, :] + 1].add(high, mode="drop")
    return out.reshape(*lead, W)


def unpack_bits(words: Array, bits: int, n_codes: int) -> Array:
    """Inverse of pack_bits: [..., W] uint32 -> [..., n_codes] uint8.

    Gather indices are computed at trace time (static), so the lowered HLO is
    a regular gather + shift + mask — the shape the MXU/VPU wants.
    """
    if not (1 <= bits <= 8):
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    j = np.arange(n_codes)
    word_idx = jnp.asarray((j * bits) >> 5)
    bit_in = jnp.asarray((j * bits) & 31, dtype=np.uint32)
    w0 = jnp.take(words, word_idx, axis=-1)
    low = w0 >> bit_in
    # Bits spilling from the next word (index clamped; masked out when unused).
    word_next = jnp.minimum(word_idx + 1, words.shape[-1] - 1)
    w1 = jnp.take(words, word_next, axis=-1)
    spill = (w1 << (jnp.uint32(31) - bit_in)) << 1
    has_spill = (bit_in + jnp.uint32(bits) > 32).astype(jnp.uint32)
    val = (low | (spill * has_spill)) & jnp.uint32((1 << bits) - 1)
    return val.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# No-straddle layout: each u32 word holds floor(32/bits) whole codes.
#
# Wastes (32 mod bits) pad bits per word (e.g. 2/32 = 6.25% at b=5) but makes
# unpacking gather-free: a reshape + broadcast shift + mask, which is exactly
# what a TPU VPU wants and what the Pallas fused kernel uses per VMEM tile.
# ---------------------------------------------------------------------------


def codes_per_word(bits: int) -> int:
    return 32 // bits


def nostraddle_words(n_codes: int, bits: int) -> int:
    return (n_codes + codes_per_word(bits) - 1) // codes_per_word(bits)


def pack_nostraddle(codes: Array, bits: int) -> Array:
    """[..., L] uint8 -> [..., nostraddle_words(L, bits)] uint32."""
    if not (1 <= bits <= 16):
        raise ValueError(f"bits must be in [1, 16], got {bits}")
    *lead, L = codes.shape
    cpw = codes_per_word(bits)
    W = nostraddle_words(L, bits)
    pad = W * cpw - L
    c = codes.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    if pad:
        c = jnp.concatenate([c, jnp.zeros((*lead, pad), jnp.uint32)], axis=-1)
    c = c.reshape(*lead, W, cpw)
    shifts = jnp.asarray(np.arange(cpw) * bits, dtype=jnp.uint32)
    return jnp.sum(c << shifts, axis=-1).astype(jnp.uint32)


def unpack_nostraddle(words: Array, bits: int, n_codes: int) -> Array:
    """Inverse of pack_nostraddle — reshape/shift/mask only, no gathers."""
    *lead, W = words.shape
    cpw = codes_per_word(bits)
    shifts = jnp.asarray(np.arange(cpw) * bits, dtype=jnp.uint32)
    vals = (words[..., None] >> shifts) & jnp.uint32((1 << bits) - 1)
    vals = vals.reshape(*lead, W * cpw)
    return vals[..., :n_codes].astype(jnp.uint8)


def unpack_nostraddle_tile(words: Array, bits: int, n_codes: int) -> Array:
    """No-straddle unpack of one flat [W] u32 tile -> [n_codes] uint32.

    Same math as ``unpack_nostraddle`` but the shift table is a
    ``broadcasted_iota`` generated in-graph, so the function is safe inside a
    Pallas kernel body (a captured host array would lower as a Mosaic
    constant).  This is the decode the fused attention kernel runs per VMEM
    tile; layouts hand it to the kernel through their ``tile_decode`` hook.
    """
    cpw = codes_per_word(bits)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, cpw), 1) * jnp.uint32(bits)
    vals = (words[:, None] >> shifts) & jnp.uint32((1 << bits) - 1)
    return vals.reshape(-1)[:n_codes]


def choose_bits(codes: Array, axes: tuple[int, ...], pow2: bool = False) -> Array:
    """Per-block bit width: ceil(log2(max+1)), min 1; optionally rounded up
    to {1,2,4,8} so a kernel can lax.switch over four unpack variants."""
    mx = jnp.max(codes.astype(jnp.int32), axis=axes)
    b = jnp.ceil(jnp.log2(jnp.maximum(mx, 1).astype(jnp.float32) + 1.0)).astype(jnp.int32)
    b = jnp.maximum(b, 1)
    if pow2:
        b = jnp.int32(1) << jnp.ceil(jnp.log2(b.astype(jnp.float32))).astype(jnp.int32)
    return b


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdaptivePacked:
    """Ragged container: per-block adaptive widths, deterministic offsets.

    payload : uint32 [capacity_words] — blocks packed back to back.
    offsets : int32 [n_blocks] — word offset of each block (exclusive cumsum
        of per-block word counts: the atomic-free Block Offsets Array).
    bits    : int32 [n_blocks] — width used by each block.
    nwords  : int32 [n_blocks] — words used by each block.
    """

    payload: Array
    offsets: Array
    bits: Array
    nwords: Array
    block_codes: int  # static: codes per block

    def tree_flatten(self):
        return (self.payload, self.offsets, self.bits, self.nwords), self.block_codes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, block_codes=aux)

    @property
    def payload_bits(self) -> Array:
        return jnp.sum(self.nwords) * 32

    @property
    def meta_bits(self) -> int:
        # u32 offset + u8 width per block.
        return int(self.offsets.shape[0]) * (32 + 8)


def pack_adaptive(codes: Array, capacity_words: int, pow2: bool = False) -> AdaptivePacked:
    """Pack [n_blocks, block_codes] codes with per-block adaptive widths.

    Strategy (vectorized, no data-dependent shapes): pack every block at each
    candidate width, then for each block scatter the words of its chosen
    width into the flat payload at its cumsum offset.
    """
    n_blocks, L = codes.shape
    widths = (1, 2, 4, 8) if pow2 else tuple(range(1, 9))
    bits = choose_bits(codes, axes=(1,), pow2=pow2)  # [n_blocks]
    per_block_words = (L * bits + 31) // 32
    offsets = jnp.cumsum(per_block_words) - per_block_words
    payload = jnp.zeros((capacity_words,), jnp.uint32)
    for b in widths:
        Wb = packed_words(L, b)
        pk = pack_bits(codes, b)  # [n_blocks, Wb]
        sel = (bits == b)
        # Scatter only selected blocks' words; unselected scatter to a dump slot.
        tgt = jnp.where(sel[:, None], offsets[:, None] + jnp.arange(Wb)[None, :], capacity_words)
        payload = payload.at[tgt.reshape(-1)].add(
            jnp.where(sel[:, None], pk, 0).reshape(-1), mode="drop"
        )
    return AdaptivePacked(
        payload=payload,
        offsets=offsets.astype(jnp.int32),
        bits=bits.astype(jnp.int32),
        nwords=per_block_words.astype(jnp.int32),
        block_codes=L,
    )


def unpack_adaptive(packed: AdaptivePacked) -> Array:
    """Inverse of pack_adaptive -> uint8 [n_blocks, block_codes]."""
    L = packed.block_codes
    n_blocks = packed.offsets.shape[0]
    widths = tuple(range(1, 9))
    out = jnp.zeros((n_blocks, L), jnp.uint8)
    for b in widths:
        Wb = packed_words(L, b)
        idx = packed.offsets[:, None] + jnp.arange(Wb)[None, :]
        idx = jnp.minimum(idx, packed.payload.shape[0] - 1)
        words = packed.payload[idx]  # [n_blocks, Wb]
        vals = unpack_bits(words, b, L)
        out = jnp.where((packed.bits == b)[:, None], vals, out)
    return out


# ---------------------------------------------------------------------------
# Numpy oracle (for kernel/property tests)
# ---------------------------------------------------------------------------


def pack_bits_np(codes: np.ndarray, bits: int) -> np.ndarray:
    codes = np.asarray(codes, np.uint32) & ((1 << bits) - 1)
    *lead, L = codes.shape
    W = packed_words(L, bits)
    out = np.zeros((*lead, W), np.uint32)
    flat_c = codes.reshape(-1, L)
    flat_o = out.reshape(-1, W)
    for j in range(L):
        pos = j * bits
        w, s = pos >> 5, pos & 31
        flat_o[:, w] |= (flat_c[:, j] << s) & 0xFFFFFFFF
        if s + bits > 32:
            flat_o[:, w + 1] |= flat_c[:, j] >> (32 - s)
    return out


def unpack_bits_np(words: np.ndarray, bits: int, n_codes: int) -> np.ndarray:
    words = np.asarray(words, np.uint64)
    *lead, W = words.shape
    flat_w = words.reshape(-1, W)
    out = np.zeros((flat_w.shape[0], n_codes), np.uint8)
    mask = (1 << bits) - 1
    for j in range(n_codes):
        pos = j * bits
        w, s = pos >> 5, pos & 31
        v = flat_w[:, w] >> s
        if s + bits > 32 and w + 1 < W:
            v |= flat_w[:, w + 1] << (32 - s)
        out[:, j] = (v & mask).astype(np.uint8)
    return out.reshape(*lead, n_codes)
