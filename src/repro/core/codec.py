"""KVComp compression pipelines: quantization ∘ entropy coding (paper §3).

Two pipelines share the §3.1.1 quantizer:

* ``HuffmanPipeline`` — the faithful maximal-ratio path: per-layer shared
  canonical codebooks (built once from prefill histograms, §3.2), streams
  packed with deterministic cumsum offsets.
* ``PackedPipeline`` — the TPU-native path: per-block adaptive fixed-length
  packing (DESIGN.md §2).

Both report compression ratios with *full* metadata accounting, mirroring the
paper's ~1/128 metadata analysis: per-unit fp16 (min, step), per-stream u16
bit counts, per-block u32 offsets, and the codebook itself.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, huffman, quant

RAW_BITS_PER_VALUE = 16  # KV caches are bf16/fp16 at rest


@dataclasses.dataclass(frozen=True)
class RatioReport:
    """Exact size accounting for one compressed tensor."""

    n_values: int
    payload_bits: int
    scale_bits: int
    stream_meta_bits: int
    offset_meta_bits: int
    codebook_bits: int

    @property
    def total_bits(self) -> int:
        return (
            self.payload_bits
            + self.scale_bits
            + self.stream_meta_bits
            + self.offset_meta_bits
            + self.codebook_bits
        )

    @property
    def ratio(self) -> float:
        return self.n_values * RAW_BITS_PER_VALUE / max(self.total_bits, 1)

    @property
    def bits_per_value(self) -> float:
        return self.total_bits / max(self.n_values, 1)


def _scale_bits(q: quant.Quantized) -> int:
    return q.meta_bits


def kivi_ratio(q: quant.Quantized, bits: int) -> RatioReport:
    """KIVI baseline: fixed b-bit payload + fp16 (min, step) per unit."""
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=int(q.codes.size) * bits,
        scale_bits=_scale_bits(q),
        stream_meta_bits=0,
        offset_meta_bits=0,
        codebook_bits=0,
    )


def huffman_ratio(q: quant.Quantized, book: huffman.CodeBook, streams_shape: tuple[int, int]) -> RatioReport:
    """KVComp Huffman path sizes from the histogram (exact expected bits)."""
    hist = np.bincount(np.asarray(q.codes).reshape(-1), minlength=huffman.N_SYMBOLS)
    payload = int((hist * book.lengths).sum())
    n_streams = int(np.prod(q.codes.shape)) // streams_shape[1]
    n_blocks = max(n_streams // streams_shape[0], 1)
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=payload,
        scale_bits=_scale_bits(q),
        stream_meta_bits=n_streams * 16,  # u16 bit count per stream (per-thread metadata)
        offset_meta_bits=n_blocks * 32,  # u32 offset per block (Block Offsets Array)
        codebook_bits=book.serialized_bits,
    )


def packed_ratio(q: quant.Quantized, block_codes: int) -> RatioReport:
    """TPU adaptive fixed-length path sizes."""
    codes = np.asarray(q.codes).reshape(-1, block_codes)
    mx = codes.max(axis=1).astype(np.int64)
    b = np.maximum(np.ceil(np.log2(mx + 1)), 1).astype(np.int64)
    payload = int((((block_codes * b) + 31) // 32 * 32).sum())
    n_blocks = codes.shape[0]
    return RatioReport(
        n_values=int(q.codes.size),
        payload_bits=payload,
        scale_bits=_scale_bits(q),
        stream_meta_bits=n_blocks * 8,  # u8 width per block
        offset_meta_bits=n_blocks * 32,
        codebook_bits=0,
    )


@dataclasses.dataclass
class KVCompCodec:
    """End-to-end codec with per-layer shared codebooks (paper §3.2).

    Typical flow::

        codec = KVCompCodec(quant.QuantConfig(...))
        codec.fit(k_prefill, v_prefill)          # build codebooks once
        qk = codec.quantize_k(k)                 # lossy step
        report = codec.report_k(qk)              # exact size accounting
    """

    cfg: quant.QuantConfig
    book_k: huffman.CodeBook | None = None
    book_v: huffman.CodeBook | None = None

    # -- lossy step ---------------------------------------------------------
    def quantize_k(self, k) -> quant.Quantized:
        if self.cfg.k_granularity == "block":
            return quant.quantize_k_block(k, self.cfg.rel_scale_k, self.cfg.block_size)
        return quant.quantize_k_channel(k, self.cfg.rel_scale_k)

    def quantize_v(self, v) -> quant.Quantized:
        return quant.quantize_v_token(v, self.cfg.rel_scale_v)

    # -- codebooks (prefill-time, host) --------------------------------------
    def fit(self, k, v) -> None:
        qk, qv = self.quantize_k(k), self.quantize_v(v)
        self.book_k = huffman.build_codebook(np.asarray(huffman.histogram(qk.codes)))
        self.book_v = huffman.build_codebook(np.asarray(huffman.histogram(qv.codes)))

    # -- size accounting ------------------------------------------------------
    def report_k(self, qk: quant.Quantized, mode: str = "huffman") -> RatioReport:
        head_dim = qk.codes.shape[-1]
        if mode == "huffman":
            assert self.book_k is not None, "call fit() first"
            return huffman_ratio(qk, self.book_k, (self.cfg.block_size, head_dim))
        if mode == "packed":
            return packed_ratio(qk, self.cfg.block_size * head_dim)
        if mode == "kivi":
            return kivi_ratio(qk, self.cfg.kivi_bits)
        raise ValueError(mode)

    def report_v(self, qv: quant.Quantized, mode: str = "huffman") -> RatioReport:
        head_dim = qv.codes.shape[-1]
        if mode == "huffman":
            assert self.book_v is not None, "call fit() first"
            return huffman_ratio(qv, self.book_v, (self.cfg.block_size, head_dim))
        if mode == "packed":
            return packed_ratio(qv, self.cfg.block_size * head_dim)
        if mode == "kivi":
            return kivi_ratio(qv, self.cfg.kivi_bits)
        raise ValueError(mode)

    # -- full encode/decode (ragged Huffman container) ------------------------
    def encode_huffman(self, q: quant.Quantized, which: str = "k"):
        """Encode quantized codes into the ragged layout. Returns
        (payload u32, nbits u16 [streams], codes_shape)."""
        book = self.book_k if which == "k" else self.book_v
        assert book is not None, "call fit() first"
        shape = q.codes.shape
        head_dim = shape[-1]
        streams = q.codes.reshape(-1, head_dim)
        cl, ln = book.as_encode_tables()
        cap = streams.size * huffman.WORST_BITS_PER_SYMBOL // 32 + 2
        payload, nbits, total = huffman.encode_block_jax(streams, cl, ln, cap)
        return payload, nbits, shape

    def decode_huffman(self, payload, nbits, codes_shape, which: str = "k", max_stream_bits: int | None = None):
        book = self.book_k if which == "k" else self.book_v
        assert book is not None
        head_dim = codes_shape[-1]
        ch, isym, sym = book.as_device_tables()
        if max_stream_bits is None:
            max_stream_bits = head_dim * huffman.WORST_BITS_PER_SYMBOL
        out = huffman.decode_block_jax(payload, nbits, ch, isym, sym, head_dim, max_stream_bits)
        return out.reshape(codes_shape)

    # -- Packed (TPU path) ----------------------------------------------------
    def encode_packed(self, q: quant.Quantized, pow2: bool = True) -> bitpack.AdaptivePacked:
        shape = q.codes.shape
        block_codes = self.cfg.block_size * shape[-1]
        codes2d = q.codes.reshape(-1, block_codes)
        cap = codes2d.size // 4 + codes2d.shape[0]  # ≥ worst case 8 bits/value
        return bitpack.pack_adaptive(codes2d, capacity_words=cap, pow2=pow2)

    def decode_packed(self, packed: bitpack.AdaptivePacked, codes_shape):
        return bitpack.unpack_adaptive(packed).reshape(codes_shape)


def compute_histogram_figure(qcodes, n_show: int = 32) -> np.ndarray:
    """Paper Fig. 3 analogue: histogram of quantized KV codes."""
    h = np.bincount(np.asarray(qcodes).reshape(-1), minlength=256)
    return h[:n_show]
