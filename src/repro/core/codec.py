"""KVComp compression pipelines: quantization ∘ entropy coding (paper §3).

Two pipelines share the §3.1.1 quantizer:

* ``HuffmanPipeline`` — the faithful maximal-ratio path: per-layer shared
  canonical codebooks (built once from prefill histograms, §3.2), streams
  packed with deterministic cumsum offsets.
* ``PackedPipeline`` — the TPU-native path: per-block adaptive fixed-length
  packing (DESIGN.md §2).

Both report compression ratios with *full* metadata accounting, mirroring the
paper's ~1/128 metadata analysis.  The accounting itself lives with the cache
layouts (``repro.core.layouts`` — every ``CacheLayout`` owns its
``size_report``); this module re-exports the report helpers for backward
compatibility and adds the host-side codebook-fitting flow.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, huffman, layouts, quant
from repro.core.layouts import (  # noqa: F401  (re-exported public API)
    RAW_BITS_PER_VALUE,
    RatioReport,
    huffman_ratio,
    kivi_ratio,
    packed_ratio,
)


@dataclasses.dataclass
class KVCompCodec:
    """End-to-end codec with per-layer shared codebooks (paper §3.2).

    Typical flow::

        codec = KVCompCodec(quant.QuantConfig(...))
        codec.fit(k_prefill, v_prefill)          # build codebooks once
        qk = codec.quantize_k(k)                 # lossy step
        report = codec.report_k(qk)              # exact size accounting

    Size reports dispatch through the cache-layout registry, so any
    registered layout name is a valid ``mode``.
    """

    cfg: quant.QuantConfig
    book_k: huffman.CodeBook | None = None
    book_v: huffman.CodeBook | None = None

    # -- lossy step ---------------------------------------------------------
    def quantize_k(self, k) -> quant.Quantized:
        if self.cfg.k_granularity == "block":
            return quant.quantize_k_block(k, self.cfg.rel_scale_k, self.cfg.block_size)
        return quant.quantize_k_channel(k, self.cfg.rel_scale_k)

    def quantize_v(self, v) -> quant.Quantized:
        return quant.quantize_v_token(v, self.cfg.rel_scale_v)

    # -- codebooks (prefill-time, host) --------------------------------------
    def fit(self, k, v) -> None:
        qk, qv = self.quantize_k(k), self.quantize_v(v)
        self.book_k = huffman.build_codebook(np.asarray(huffman.histogram(qk.codes)))
        self.book_v = huffman.build_codebook(np.asarray(huffman.histogram(qv.codes)))

    # -- size accounting ------------------------------------------------------
    def _report(self, q: quant.Quantized, mode: str, book) -> RatioReport:
        if mode == "huffman":
            assert book is not None, "call fit() first"
        return layouts.get_layout(mode).size_report(
            q, block_size=self.cfg.block_size, head_dim=q.codes.shape[-1],
            kivi_bits=self.cfg.kivi_bits, book=book)

    def report_k(self, qk: quant.Quantized, mode: str = "huffman") -> RatioReport:
        return self._report(qk, mode, self.book_k)

    def report_v(self, qv: quant.Quantized, mode: str = "huffman") -> RatioReport:
        return self._report(qv, mode, self.book_v)

    # -- full encode/decode (ragged Huffman container) ------------------------
    def encode_huffman(self, q: quant.Quantized, which: str = "k"):
        """Encode quantized codes into the ragged layout. Returns
        (payload u32, nbits u16 [streams], codes_shape)."""
        book = self.book_k if which == "k" else self.book_v
        assert book is not None, "call fit() first"
        shape = q.codes.shape
        head_dim = shape[-1]
        streams = q.codes.reshape(-1, head_dim)
        cl, ln = book.as_encode_tables()
        cap = streams.size * huffman.WORST_BITS_PER_SYMBOL // 32 + 2
        payload, nbits, total = huffman.encode_block_jax(streams, cl, ln, cap)
        return payload, nbits, shape

    def decode_huffman(self, payload, nbits, codes_shape, which: str = "k"):
        # Chunked LUT decode is symbol-bounded (one codeword per probe pair),
        # so the old bit-bound parameter is gone with the bit-serial walk.
        book = self.book_k if which == "k" else self.book_v
        assert book is not None
        head_dim = codes_shape[-1]
        out = huffman.decode_block_lut_jax(
            payload, nbits, jnp.asarray(book.decode_lut()),
            head_dim, book.decode_probes)
        return out.reshape(codes_shape)

    # -- Packed (TPU path) ----------------------------------------------------
    def encode_packed(self, q: quant.Quantized, pow2: bool = True) -> bitpack.AdaptivePacked:
        shape = q.codes.shape
        block_codes = self.cfg.block_size * shape[-1]
        codes2d = q.codes.reshape(-1, block_codes)
        cap = codes2d.size // 4 + codes2d.shape[0]  # ≥ worst case 8 bits/value
        return bitpack.pack_adaptive(codes2d, capacity_words=cap, pow2=pow2)

    def decode_packed(self, packed: bitpack.AdaptivePacked, codes_shape):
        return bitpack.unpack_adaptive(packed).reshape(codes_shape)


def compute_histogram_figure(qcodes, n_show: int = 32) -> np.ndarray:
    """Paper Fig. 3 analogue: histogram of quantized KV codes."""
    h = np.bincount(np.asarray(qcodes).reshape(-1), minlength=256)
    return h[:n_show]
