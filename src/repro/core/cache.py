"""Compressed KV-cache manager (paper §3.2.1, §3.2.3) — jit/pjit-friendly.

This is the serving-side realization of KVComp: a per-layer cache that keeps
its main storage *compressed* (block-quantized + encoded) and a small raw
append buffer.  Newly generated KV vectors accumulate in the buffer; when it
fills one compression block, the block is quantized, encoded, and written into
the store at a deterministic slot (the atomic-free Block Offsets Array of
DESIGN.md §2 degenerates to ``slot = n_flushed % NB`` because every layout
uses uniform per-block slot widths → offsets are affine in the block index).

How a block is encoded — and how it is fetched back — is owned entirely by
the ``CacheLayout`` strategy named in ``CacheSpec.layout`` (DESIGN.md §4);
this module holds only the layout-independent machinery: the ring of block
slots, the raw tail buffer, prefill/append scheduling, and the joint-softmax
attention over (store ∥ buffer).

Faithfulness notes
------------------
* The raw tail buffer doubles as KIVI's "residual window": the most recent
  ``block_size`` tokens are always exact.
* K uses BlockQuant (per block × head × channel min/max), V uses TokenQuant
  (per token × head) — the paper's granularities.
* Sliding-window models (Mixtral) evict whole blocks via a ring over the
  block axis — "block-aligned eviction composes with compression".
* Decode attention (``attend``) dispatches through the attention-backend
  registry (DESIGN.md §9): the ``fused`` backend streams compressed tiles
  into the Pallas kernel and decompresses in VMEM; the ``xla`` backend
  (``attend_blockwise``) scans the block axis decoding one block at a time
  and folds dequantization into the matvec with the *algebraic fusion*
  identity ``q·(m + s∘c) = (q·m) + (q∘s)·c``.  Neither builds a dequantized
  ``[B, Hkv, NB, T, D]`` intermediate — only the retired
  ``attend_materialized`` oracle does.

Lengths are **per row**: ``n_flushed`` and ``buf_len`` are ``i32 [B]``
vectors, so every batch row advances (appends, flushes, attends) at its own
sequence position — the contract the continuous-batching scheduler
(``repro.serve.scheduler``) relies on when requests join and leave slots
mid-flight.  Uniform batches are simply the special case where every row
holds the same value, and the structure still scans cleanly over layers.
"""

from __future__ import annotations

import dataclasses
import math
import os

import jax
import jax.numpy as jnp

from repro.core import bitpack, layouts, pool
from repro.obs.profiling import annotate

Array = jax.Array

NEG_INF = -1e9

# Re-exported: historical home of this helper (dryrun and tests import it).
bits_for_rel_scale = layouts.bits_for_rel_scale


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static (hashable — lives in the pytree aux) cache configuration.

    ``layout`` names a registered ``repro.core.layouts.CacheLayout``; bit
    widths and store shapes are delegated to it.  The optional overrides let
    a ``CompressionPolicy`` pin explicit storage widths per tensor.
    ``attn_backend`` selects the decode-attention backend
    (``repro.kernels.ops``): ``"auto"`` | ``"xla"`` | ``"fused"`` | any
    ``register_backend``-ed name.

    ``mode`` picks the storage container (DESIGN.md §10): ``"dense"`` gives
    every row its own ``n_blocks`` ring; ``"paged"`` stores blocks in one
    shared arena of ``pool_pages`` physical pages (store batch axis 1) that
    rows address through a per-row page table — the serving scheduler owns
    page allocation (``repro.core.pool``).
    """

    layout: str = "packed"  # any name in layouts.available_layouts()
    block_size: int = 64
    rel_scale_k: float = 0.05
    rel_scale_v: float = 0.15
    kivi_bits: int = 2
    max_seq: int = 4096
    window: int | None = None  # sliding-window size (tokens), None = full
    bits_k_override: int | None = None
    bits_v_override: int | None = None
    attn_backend: str = "auto"  # decode-attention backend (DESIGN.md §9)
    mode: str = "dense"  # "dense" | "paged" (shared-arena, page-indirect)
    pool_pages: int = 0  # paged: physical pages in the shared arena
    # Blockwise-scan tuning knobs (None = REPRO_BLOCKWISE_* env var, else the
    # module defaults BLOCKWISE_SPAN_TOKENS / BLOCKWISE_UNROLL_MAX below) —
    # the real-TPU tuning pass turns these instead of editing constants.
    span_tokens: int | None = None  # ~tokens decoded per scan step
    unroll_max: int | None = None   # unroll the span loop up to this many steps

    def __post_init__(self):
        if self.mode not in ("dense", "paged"):
            raise ValueError(f"mode must be dense|paged, got {self.mode!r}")
        for f in ("span_tokens", "unroll_max"):
            val = getattr(self, f)
            if val is not None and val < 1:
                raise ValueError(f"{f} must be >= 1 when set, got {val}")
        if self.mode == "paged" and self.pool_pages < 1:
            raise ValueError(
                f"paged mode needs pool_pages >= 1, got {self.pool_pages}")
        if self.window is not None and self.window % self.block_size:
            # A non-divisible window would make the ring silently retain
            # block_size-aligned spans shorter than the window claims.
            raise ValueError(
                f"block_size ({self.block_size}) must divide window "
                f"({self.window}): the sliding-window ring evicts whole "
                f"compression blocks")

    @property
    def impl(self) -> layouts.CacheLayout:
        return layouts.get_layout(self.layout)

    @property
    def paged(self) -> bool:
        return self.mode == "paged"

    @property
    def bits_k(self) -> int:
        if self.bits_k_override is not None:
            return self.bits_k_override
        return self.impl.bits_k(self)

    @property
    def bits_v(self) -> int:
        if self.bits_v_override is not None:
            return self.bits_v_override
        return self.impl.bits_v(self)

    @property
    def n_blocks(self) -> int:
        """Logical ring length: blocks addressable per row (page-table width
        in paged mode)."""
        span = self.max_seq if self.window is None else min(self.window, self.max_seq)
        return max(1, math.ceil(span / self.block_size))

    @property
    def store_blocks(self) -> int:
        """Physical extent of the store's block axis: the shared arena's
        page count in paged mode, the per-row ring length in dense mode."""
        return self.pool_pages if self.paged else self.n_blocks

    def words_k(self, head_dim: int) -> int:
        return bitpack.nostraddle_words(self.block_size * head_dim, self.bits_k)

    def words_v(self, head_dim: int) -> int:
        return bitpack.nostraddle_words(self.block_size * head_dim, self.bits_v)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class LayerKVCache:
    """One layer's cache.  Leading dims: [B, Hkv, ...].

    Store shapes are layout-owned (see ``CacheLayout.init_store``); e.g. the
    packed layouts use
      k_store : u32 [B, Hkv, NB, Wk]       (bit-packed block codes)
      k_min/k_step : bf16 [B, Hkv, NB, D]  (BlockQuant units)
      v_store : u32 [B, Hkv, NB, Wv]
      v_min/v_step : bf16 [B, Hkv, NB, T]  (TokenQuant units; T = block_size)
    while the raw layout stores bf16 [B, Hkv, NB, T, D] blocks with dummy
    scales.  Shared, layout-independent:
      k_buf / v_buf : bf16 [B, Hkv, T, D] — raw append buffer (residual window)
      n_flushed : i32 [B] — per-row total blocks ever flushed (ring index)
      buf_len   : i32 [B] — per-row valid entries in the buffer
      page_tab  : i32 [B, NB] — paged mode only: logical slot -> physical
                  arena page (-1 unassigned); dense mode holds a [1] dummy.
                  In paged mode the six store arrays carry batch extent 1
                  (the shared arena) with ``spec.pool_pages`` on the block
                  axis, while buffers/lengths stay per-row (DESIGN.md §10).
    """

    k_store: Array
    k_min: Array
    k_step: Array
    v_store: Array
    v_min: Array
    v_step: Array
    k_buf: Array
    v_buf: Array
    n_flushed: Array
    buf_len: Array
    page_tab: Array
    spec: CacheSpec

    # -- pytree ---------------------------------------------------------------
    # Keys are part of the flatten so path-based sharding rules
    # (distributed.sharding.cache_shardings) can match leaves by name.
    _FIELDS = ("k_store", "k_min", "k_step", "v_store", "v_min", "v_step",
               "k_buf", "v_buf", "n_flushed", "buf_len", "page_tab")

    def tree_flatten_with_keys(self):
        leaves = [(jax.tree_util.GetAttrKey(f), getattr(self, f))
                  for f in self._FIELDS]
        return leaves, self.spec

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._FIELDS), self.spec

    @classmethod
    def tree_unflatten(cls, spec, leaves):
        return cls(*leaves, spec=spec)

    # -- helpers ----------------------------------------------------------------
    def with_spec(self, spec: "CacheSpec") -> "LayerKVCache":
        """Same leaves under a different static spec.

        The sharded serving path (``repro.distributed.serve_shard``) uses
        this to rewrite ``attn_backend``/``pool_pages`` on views of a cache
        — e.g. each mesh shard attends its local page slice under a spec
        whose ``pool_pages`` is the per-shard arena extent.
        """
        return dataclasses.replace(self, spec=spec)

    @property
    def head_dim(self) -> int:
        return self.k_buf.shape[-1]

    @property
    def batch(self) -> int:
        return self.k_buf.shape[0]

    @property
    def total_len(self) -> Array:
        """Per-row tokens visible to attention (window-capped for SWA): [B]."""
        nb = jnp.minimum(self.n_flushed, self.spec.n_blocks)
        return nb * self.spec.block_size + self.buf_len


def init_layer_cache(spec: CacheSpec, batch: int, n_kv_heads: int, head_dim: int,
                     dtype=jnp.bfloat16) -> LayerKVCache:
    B, H, T, D = batch, n_kv_heads, spec.block_size, head_dim
    # Paged mode: the stores are ONE shared arena (batch extent 1, pool_pages
    # on the block axis — see spec.store_blocks); rows address it through
    # page_tab.  Buffers and length vectors stay per-row either way.
    k_store, k_min, k_step, v_store, v_min, v_step = spec.impl.init_store(
        spec, 1 if spec.paged else B, H, D, dtype)
    page_tab = (jnp.full((B, spec.n_blocks), -1, jnp.int32) if spec.paged
                else jnp.zeros((1,), jnp.int32))
    return LayerKVCache(
        k_store=k_store, k_min=k_min, k_step=k_step,
        v_store=v_store, v_min=v_min, v_step=v_step,
        k_buf=jnp.zeros((B, H, T, D), dtype),
        v_buf=jnp.zeros((B, H, T, D), dtype),
        n_flushed=jnp.zeros((B,), jnp.int32),
        buf_len=jnp.zeros((B,), jnp.int32),
        page_tab=page_tab,
        spec=spec,
    )


# ---------------------------------------------------------------------------
# Prefill: bulk-compress a prompt's KV (paper Store stage, prefill phase)
# ---------------------------------------------------------------------------


def prefill(spec: CacheSpec, k: Array, v: Array, dtype=jnp.bfloat16) -> LayerKVCache:
    """Build a cache from prompt KV [B, Hkv, S, D]; whole blocks are
    compressed, the remainder lands in the raw buffer."""
    if spec.paged:
        # Bulk prefill writes a private dense ring; paged arenas are
        # populated by the serving scheduler (solo dense prefill spliced via
        # pool.splice_row) or by pool.from_dense.  See DESIGN.md §10.
        raise ValueError(
            "prefill builds dense caches; compress under the dense twin of "
            "this spec and re-house it with repro.core.pool.from_dense")
    B, H, S, D = k.shape
    T, NB = spec.block_size, spec.n_blocks
    n_full = S // T
    cache = init_layer_cache(spec, B, H, D, dtype)
    # Window models only retain the last NB blocks.
    keep = min(n_full, NB)
    if n_full:
        kb = k[:, :, (n_full - keep) * T : n_full * T].reshape(B, H, keep, T, D)
        vb = v[:, :, (n_full - keep) * T : n_full * T].reshape(B, H, keep, T, D)
        slots = jnp.broadcast_to(
            ((jnp.arange(keep) + (n_full - keep)) % NB)[None], (B, keep))
        (cache.k_store, cache.k_min, cache.k_step,
         cache.v_store, cache.v_min, cache.v_step) = spec.impl.write_blocks(
            spec, cache, slots, kb, vb)
    rem = S - n_full * T
    if rem:
        cache.k_buf = cache.k_buf.at[:, :, :rem].set(k[:, :, n_full * T :].astype(dtype))
        cache.v_buf = cache.v_buf.at[:, :, :rem].set(v[:, :, n_full * T :].astype(dtype))
    cache.n_flushed = jnp.full((B,), n_full, jnp.int32)
    cache.buf_len = jnp.full((B,), rem, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# Decode-step append (paper §3.2.3 Natural Data Appending)
# ---------------------------------------------------------------------------


def append(cache: LayerKVCache, k_new: Array, v_new: Array) -> LayerKVCache:
    """Append one token's KV [B, Hkv, D]; flush a row's buffer into a
    compressed block when it fills.  Every row appends at its own ``buf_len``
    and flushes independently (rows of a continuous batch are at different
    positions).  Pure function — returns the updated cache."""
    spec = cache.spec
    T, NB = spec.block_size, spec.n_blocks
    dt = cache.k_buf.dtype
    pos = cache.buf_len  # [B]
    sel = jnp.arange(T)[None, :] == pos[:, None]  # [B, T] one-hot per row
    k_buf = jnp.where(sel[:, None, :, None], k_new[:, :, None, :].astype(dt),
                      cache.k_buf)
    v_buf = jnp.where(sel[:, None, :, None], v_new[:, :, None, :].astype(dt),
                      cache.v_buf)
    will_flush = (pos + 1) == T  # [B]

    B, H, _, D = k_buf.shape
    kb = k_buf[:, :, None]  # [B, H, 1, T, D]
    vb = v_buf[:, :, None]
    # NB = out-of-range drop sentinel for rows whose buffer did not fill.
    slots = jnp.where(will_flush, cache.n_flushed % NB, NB)[:, None]  # [B, 1]
    if spec.paged:
        # Page-indirect flush: logical ring slots translate through the page
        # table to physical arena pages (the scheduler assigned them before
        # this step); unassigned slots become the arena's drop sentinel, so
        # a retired row's garbage flush can never corrupt a reused page.
        slots = pool.lookup_slots(cache.page_tab, slots, NB, spec.pool_pages)
    staged = dataclasses.replace(cache, k_buf=k_buf, v_buf=v_buf)
    # Skip the encode entirely on the (T-1)/T steps where no row flushes —
    # every write would be dropped, and for entropy-coding layouts the dead
    # encode is the dominant per-token cost.
    (k_store, k_min, k_step, v_store, v_min, v_step) = jax.lax.cond(
        jnp.any(will_flush),
        lambda c: spec.impl.write_blocks(spec, c, slots, kb, vb),
        lambda c: (c.k_store, c.k_min, c.k_step, c.v_store, c.v_min, c.v_step),
        staged)
    return LayerKVCache(
        k_store=k_store, k_min=k_min, k_step=k_step,
        v_store=v_store, v_min=v_min, v_step=v_step,
        k_buf=k_buf, v_buf=v_buf,
        n_flushed=cache.n_flushed + will_flush.astype(jnp.int32),
        buf_len=jnp.where(will_flush, 0, pos + 1),
        page_tab=cache.page_tab,
        spec=spec,
    )


# ---------------------------------------------------------------------------
# Decode attention over the compressed cache (paper Fetch stage)
# ---------------------------------------------------------------------------


def attend(cache: LayerKVCache, q: Array, scale: float | None = None,
           backend: str | None = None) -> Array:
    """Single-token attention against the cache — the decode entry point.

    q : [B, H, D] with H = Hkv * G (GQA); returns [B, H, D].
    Dispatches through the layout's ``attend_block`` into the
    attention-backend registry (``repro.kernels.ops``): ``fused`` runs the
    Pallas in-situ-decompression kernel, ``xla`` the blockwise
    lazily-dequantized scan below.  ``backend=None`` defers to the cache
    spec's ``attn_backend`` (default ``"auto"``: fused on TPU for
    fused-capable layouts, blockwise elsewhere).  Neither path ever
    materializes a ``[B, Hkv, NB, T, D]`` dequantized intermediate.
    """
    return cache.spec.impl.attend_block(cache, q, scale, backend=backend)


BLOCKWISE_SPAN_TOKENS = 1024  # ~tokens decoded per scan step (peak-mem knob)
BLOCKWISE_UNROLL_MAX = 64     # unroll the span loop up to this many steps

ENV_SPAN_TOKENS = "REPRO_BLOCKWISE_SPAN_TOKENS"
ENV_UNROLL_MAX = "REPRO_BLOCKWISE_UNROLL_MAX"


def blockwise_knobs(spec: CacheSpec) -> tuple[int, int]:
    """Resolve the blockwise scan's (span_tokens, unroll_max).

    Same precedence as the attention-backend knob: an explicit ``CacheSpec``
    field wins (threaded from ``CompressionPolicy``/``ModelConfig``, per
    layer overridable), else the ``REPRO_BLOCKWISE_*`` env var (read at
    trace time — the real-TPU tuning pass sweeps these without code edits),
    else the module default.
    """

    def pick(field: int | None, env: str, default: int) -> int:
        if field is not None:
            return field
        raw = os.environ.get(env)
        if not raw:
            return default
        try:
            val = int(raw)
        except ValueError:
            raise ValueError(f"{env}={raw!r} is not an integer") from None
        if val < 1:  # same bound CacheSpec enforces on the field
            raise ValueError(f"{env} must be >= 1, got {val}")
        return val

    return (pick(spec.span_tokens, ENV_SPAN_TOKENS, BLOCKWISE_SPAN_TOKENS),
            pick(spec.unroll_max, ENV_UNROLL_MAX, BLOCKWISE_UNROLL_MAX))


def attend_blockwise(cache: LayerKVCache, q: Array,
                     scale: float | None = None,
                     span: int | None = None) -> Array:
    """The generic XLA decode path: a blockwise lazily-dequantized
    flash-decode scan (the ``"xla"`` attention backend).

    Running ``(m, l, acc)`` state walks the NB block axis in spans of a few
    blocks (``span`` blocks per step, sized so one step decodes about
    ``span_tokens`` tokens — enough matvec per step to amortize per-step
    overhead, while peak temporary state stays one span; see
    ``blockwise_knobs`` for how the spec/env/default resolve).  A span
    decodes lazily in one vectorized op through the layout's ``decode_span``
    and dequantization folds into the matvecs with the paper's algebraic
    fusion ``q·(mn + st∘c) = q·mn + q·(st∘c)`` (and its V-side mirror) —
    never the ``[B, Hkv, NB, T, D]`` store nor a ``[B, Hkv, G, NB*T+T]``
    logits concat.  Up to ``unroll_max`` steps the loop unrolls
    (XLA fuses each span chain and reuses one span's buffers — measurably
    faster than both lax.scan and the materializing attend on CPU); past
    that (very long contexts) it switches to ``lax.scan`` to keep the HLO
    bounded.  The raw buffer tail merges via the same two-part softmax
    combine the fused kernel path uses.  Any registered layout gets this
    path for free (huffman LUT-decodes one span per step).
    """
    from repro.kernels import ref as kref  # shared combine; late: kernels import core

    spec = cache.spec
    B, Hq, D = q.shape
    Hkv = cache.k_buf.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    with annotate("blockwise_span_scan"):
        m, l, acc = _store_scan(cache, qg, scale, span)
    out = kref.combine_with_buffer_ref(
        acc.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq),
        q, cache.k_buf, cache.v_buf, cache.buf_len, scale=scale)
    return out.astype(q.dtype)


def _store_scan(cache: LayerKVCache, qg: Array, scale: float,
                span: int | None = None):
    """The blockwise flash-decode scan over the FLUSHED store only: running
    ``(m, l, acc)`` softmax state per grouped query, without the raw-buffer
    combine (callers merge their own tail — ``attend_blockwise`` the buffer,
    ``attend_chunk`` the chunk's intra-causal raw scores).

    ``qg``: f32 ``[B, Hkv, G', D]`` — generic in the grouped-query axis, so
    the chunked-prefill path folds its ``C`` chunk positions into
    ``G' = C * G`` and reuses this scan unchanged (every flushed block is
    strictly in the past of every chunk token, so all G' queries see the
    same mask).  Returns ``(m [B,Hkv,G'], l [B,Hkv,G'], acc [B,Hkv,G',D])``
    with ``m = NEG_INIT, l = 0`` rows where nothing is flushed.
    """
    from repro.kernels import ref as kref  # shared constants; late import

    spec = cache.spec
    B, Hkv, G, D = qg.shape
    T, NB = spec.block_size, spec.n_blocks
    span_tokens, unroll_max = blockwise_knobs(spec)
    if span is None:
        span = max(1, span_tokens // T)
    span = min(span, NB)
    n_steps = -(-NB // span)
    nb_valid = jnp.minimum(cache.n_flushed, NB)  # [B]
    impl = spec.impl
    f32 = jnp.float32

    def body(carry, n0):
        m, l, acc = carry
        # One contiguous span [start, start+span) decodes in one vectorized
        # layout op.  The last (ragged) span clamps its window back; blocks
        # before n0 in the clamped window were already consumed, so the mask
        # drops them alongside not-yet-flushed slots.
        start = jnp.minimum(n0, NB - span)
        if spec.paged:
            # Gather the span's pages out of the shared arena into a dense
            # per-row view; the layout decodes it unchanged from block 0.
            kc, k_mn, k_st, vc, v_mn, v_st = impl.decode_span(
                spec, pool.span_view(cache, start, span), 0, span)
        else:
            kc, k_mn, k_st, vc, v_mn, v_st = impl.decode_span(
                spec, cache, start, span)
        has_scales = k_mn is not None
        # q·(mn + st∘c) = q·mn + q·(st∘c): the rank-1 mn term stays separate
        # (dequantized values are never formed); the step scales fold into
        # the CODES so the whole span contracts in one [G,D]x[C·T,D] matvec.
        if has_scales:
            kc = kc * k_st.astype(f32)[:, :, :, None, :]  # st∘c  [B,H,C,T,D]
        s = jnp.einsum("bhgd,bhxd->bhgx", qg,
                       kc.astype(f32).reshape(B, Hkv, span * T, D)
                       ).reshape(B, Hkv, G, span, T)
        if has_scales:
            s = s + jnp.einsum("bhgd,bhcd->bhgc", qg,
                               k_mn.astype(f32))[..., None]
        s = s * scale
        # flushed blocks are whole: per-(row, block) all-or-nothing masks
        idx = start + jnp.arange(span)  # [C]
        ok = (idx[None, :] >= n0) & (idx[None, :] < nb_valid[:, None])  # [B,C]
        if spec.paged:
            # Unassigned table entries (-1) gathered a clamped page above;
            # mask them out regardless of nb_valid — the shard-local table
            # semantics of DESIGN.md §12, where blocks hosted by another
            # shard are marked -1 and must contribute nothing.
            pg = jax.lax.dynamic_slice_in_dim(cache.page_tab, start, span, 1)
            ok = ok & (pg >= 0)
        okx = ok[:, None, None, :, None]
        s = jnp.where(okx, s, kref.NEG_INIT)
        s2 = s.reshape(B, Hkv, G, span * T)
        m_new = jnp.maximum(m, jnp.max(s2, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = (jnp.exp(s - m_new[..., None, None]) * okx)  # [B,H,G,C,T]
        l_new = l * alpha + jnp.sum(p, axis=(-2, -1))
        # V mirror: Σ p·(mn + st∘c) = (p·mn) + ((p∘st)·c)
        if has_scales:
            pv = p * v_st.astype(f32)[:, :, None]  # p∘st  [B,H,G,C,T]
            upd = (jnp.einsum("bhgct,bhct->bhg", p, v_mn.astype(f32))[..., None]
                   + jnp.einsum("bhgx,bhxd->bhgd",
                                pv.reshape(B, Hkv, G, span * T),
                                vc.astype(f32).reshape(B, Hkv, span * T, D)))
        else:
            upd = jnp.einsum("bhgx,bhxd->bhgd",
                             p.reshape(B, Hkv, G, span * T),
                             vc.astype(f32).reshape(B, Hkv, span * T, D))
        acc_new = acc * alpha[..., None] + upd
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G), kref.NEG_INIT, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, D), jnp.float32)
    if n_steps <= unroll_max:
        carry = (m0, l0, acc0)
        for i in range(n_steps):
            carry, _ = body(carry, i * span)
        return carry
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  jnp.arange(n_steps) * span)
    return m, l, acc


# ---------------------------------------------------------------------------
# Block-chunked prefill (DESIGN.md §11/§13) — since chunked admission became
# the scheduler default, this is the path EVERY served prompt takes: solo
# admission drains all chunks at once, chunked admission splices them
# between decode steps, and both reduce to the same per-block computation.
# ---------------------------------------------------------------------------


def attend_chunk(cache: LayerKVCache, q: Array, k_new: Array, v_new: Array,
                 scale: float | None = None) -> Array:
    """Attention for one block-chunked prefill step: ``C`` new tokens attend
    the flushed compressed store plus the chunk's own raw K/V causally.

    ``q``: ``[B, C, Hq, D]``; ``k_new``/``v_new``: ``[B, Hkv, C, D]``.
    Chunks start at block boundaries (the raw buffer is empty), so each
    token's visible set is exactly what the decode path would give it: all
    flushed blocks through the store (lazily dequantized — the lossy side)
    plus the raw tokens of its own partial block (the exact side, self
    included).  The store partials come from the same ``_store_scan`` the
    decode backend runs, with the chunk axis folded into the grouped-query
    axis (``G' = C*G`` — every flushed block is strictly past every chunk
    token), then merge with the intra-chunk causal scores by the usual
    two-part online-softmax combine.  Per-block output is therefore a pure
    function of (params, pages so far, block tokens): resuming at block
    ``j`` from cached pages is bit-identical to chunking from token 0.
    """
    from repro.kernels import ref as kref  # shared constants; late import

    B, C, Hq, D = q.shape
    Hkv = k_new.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    f32 = jnp.float32
    # [B, C, Hq, D] -> [B, Hkv, C, G, D]; fold (C, G) for the store scan.
    qf = q.astype(f32).reshape(B, C, Hkv, G, D).transpose(0, 2, 1, 3, 4)
    m, l, acc = _store_scan(cache, qf.reshape(B, Hkv, C * G, D), scale)
    m = m.reshape(B, Hkv, C, G)
    l = l.reshape(B, Hkv, C, G)
    acc = acc.reshape(B, Hkv, C, G, D)
    # Intra-chunk causal raw scores (self included — the chunk counterpart
    # of decode's append-before-attend buffer visibility).
    s = jnp.einsum("bhcgd,bhxd->bhcgx", qf, k_new.astype(f32)) * scale
    causal = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]  # [C(q), C(k)]
    mask = causal[None, None, :, None, :]
    s = jnp.where(mask, s, kref.NEG_INIT)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None]) * mask
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = (acc * alpha[..., None]
               + jnp.einsum("bhcgx,bhxd->bhcgd", p, v_new.astype(f32)))
    out = acc_new / jnp.maximum(l_new, 1e-30)[..., None]  # [B,Hkv,C,G,D]
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, Hq, D).astype(q.dtype)


def append_chunk(cache: LayerKVCache, k_new: Array, v_new: Array) -> LayerKVCache:
    """Append one chunk's KV ``[B, Hkv, C, D]`` at a block boundary (the
    raw buffer must be empty — the chunked-prefill invariant).  A full
    chunk (``C == block_size``) compresses straight through the layout's
    ``write_blocks`` and leaves the buffer empty for the next chunk; a
    final partial chunk lands in the raw buffer, exactly where a token-wise
    decode of the same suffix would have left it."""
    spec = cache.spec
    T, NB = spec.block_size, spec.n_blocks
    C = k_new.shape[2]
    dt = cache.k_buf.dtype
    if not 1 <= C <= T:
        raise ValueError(f"chunk of {C} tokens vs block_size {T}")
    if C == T:
        slots = (cache.n_flushed % NB)[:, None]  # [B, 1]
        if spec.paged:
            slots = pool.lookup_slots(cache.page_tab, slots, NB, spec.pool_pages)
        kb = k_new[:, :, None].astype(dt)  # [B, H, 1, T, D]
        vb = v_new[:, :, None].astype(dt)
        (k_store, k_min, k_step, v_store, v_min, v_step) = spec.impl.write_blocks(
            spec, cache, slots, kb, vb)
        return dataclasses.replace(
            cache, k_store=k_store, k_min=k_min, k_step=k_step,
            v_store=v_store, v_min=v_min, v_step=v_step,
            n_flushed=cache.n_flushed + 1)
    return dataclasses.replace(
        cache,
        k_buf=cache.k_buf.at[:, :, :C].set(k_new.astype(dt)),
        v_buf=cache.v_buf.at[:, :, :C].set(v_new.astype(dt)),
        buf_len=jnp.full_like(cache.buf_len, C))


def attend_materialized(cache: LayerKVCache, q: Array,
                        scale: float | None = None) -> Array:
    """The retired materializing attend — kept as the oracle/baseline.

    Dequantizes the whole store via ``fetch`` into a ``[B, Hkv, NB, T, D]``
    intermediate and runs one joint softmax over (store ∥ buffer).  Exact
    same math as the pre-backend-registry production path; lives on for the
    backend-parity tests and as ``benchmarks/decode_path.py``'s baseline.
    Never dispatched to by the serving decode path.
    """
    cache = pool.to_dense(cache)  # paged: gather pages into a private ring
    spec = cache.spec
    B, Hq, D = q.shape
    Hkv = cache.k_buf.shape[1]
    G = Hq // Hkv
    T, NB = spec.block_size, spec.n_blocks
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)

    k_deq, v_deq = spec.impl.fetch(spec, cache)  # [B,Hkv,NB,T,D]
    k_deq = k_deq.astype(jnp.float32)
    v_deq = v_deq.astype(jnp.float32)
    s_main = jnp.einsum("bhgd,bhntd->bhgnt", qg, k_deq) * scale
    nb_valid = jnp.minimum(cache.n_flushed, NB)  # [B]
    # ring: any slot < nb_valid is live — per row
    block_ok = jnp.arange(NB)[None, :] < nb_valid[:, None]  # [B, NB]
    s_main = jnp.where(block_ok[:, None, None, :, None], s_main, NEG_INF)

    kb = cache.k_buf.astype(jnp.float32)
    vb = cache.v_buf.astype(jnp.float32)
    s_buf = jnp.einsum("bhgd,bhtd->bhgt", qg, kb) * scale
    buf_ok = jnp.arange(T)[None, :] < cache.buf_len[:, None]  # [B, T]
    s_buf = jnp.where(buf_ok[:, None, None, :], s_buf, NEG_INF)

    logits = jnp.concatenate([s_main.reshape(B, Hkv, G, NB * T), s_buf], axis=-1)
    w = jax.nn.softmax(logits, axis=-1)
    w_main = w[..., : NB * T].reshape(B, Hkv, G, NB, T)
    w_buf = w[..., NB * T :]
    out = jnp.einsum("bhgnt,bhntd->bhgd", w_main, v_deq)
    out = out + jnp.einsum("bhgt,bhtd->bhgd", w_buf, vb)
    return out.reshape(B, Hq, D).astype(q.dtype)


def reference_attend(k: Array, v: Array, q: Array, scale: float | None = None,
                     window: int | None = None) -> Array:
    """Oracle: exact attention over raw [B,Hkv,S,D] caches (for tests)."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32)) * scale
    if window is not None and S > window:
        keep = jnp.arange(S) >= (S - window)
        s = jnp.where(keep[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
