"""Error-bounded quantizers for KV-cache compression.

Implements the paper's two quantization granularities (§3.1.1) plus the KIVI
baseline (§4.1):

* K cache — ``BlockQuant``: the cache ``[ctx, heads, head_dim]`` is split along
  ``ctx`` into blocks of ``block_size`` tokens; within each (block, head,
  channel) unit we compute min/max and quantize with
  ``step = rel_scale * (max - min)``.
* K cache — ``ChannelQuant``: KIVI-like, min/max per (head, channel) over the
  whole segment (used as an ablation baseline; the paper's Fig. 5/7 compares
  the two).
* V cache — ``TokenQuant``: min/max per (token, head) over ``head_dim``.
* ``kivi_quantize`` — the fixed-bit-width asymmetric baseline (b ∈ {2,4}).

All quantizers share one numerical contract (property-tested):

    step  = rel_scale * (max - min)           (error-bounded form), or
    step  = (max - min) / (2^b - 1)           (fixed-bit form)
    code  = clip(round((x - min)/step), 0, n_levels-1)  -> uint8
    x_hat = min + code * step
    |x - x_hat| <= step/2 + eps   whenever code is not clipped.

Functions are pure jnp and jit-friendly; shapes are static. The "unit" axes
over which min/max is taken are the last axes after a reshape, so one
implementation serves every granularity.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

Array = jax.Array

# Number of representable code levels for the error-bounded (KVComp) path.
# Codes are stored as uint8 -> at most 256 levels; rel_scale < 1/255 would
# overflow and is clipped (the clip is part of the contract and is measured,
# not hidden: see QuantStats.clip_fraction).
N_LEVELS_U8 = 256

GranularityK = Literal["block", "channel"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the KVComp quantizer.

    rel_scale_k / rel_scale_v follow the paper's "relative quantization
    scale" in [0, 1]: the actual step for each unit is
    ``rel_scale * (max - min)`` of that unit.  Defaults are the paper's
    turning points (Fig. 5): K BlockQuant 0.05, V TokenQuant 0.15.
    """

    block_size: int = 64
    rel_scale_k: float = 0.05
    rel_scale_v: float = 0.15
    k_granularity: GranularityK = "block"
    # KIVI baseline parameters.
    kivi_bits: int = 2
    kivi_group: int = 32
    residual_window: int = 32  # recent tokens kept unquantized (KIVI-style)

    def __post_init__(self):
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive, got {self.block_size}")
        if not (0.0 < self.rel_scale_k <= 1.0) or not (0.0 < self.rel_scale_v <= 1.0):
            raise ValueError("rel_scale must be in (0, 1]")
        if self.kivi_bits not in (1, 2, 3, 4, 8):
            raise ValueError(f"kivi_bits must be in {{1,2,3,4,8}}, got {self.kivi_bits}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """A quantized tensor: integer codes + per-unit affine parameters.

    ``codes`` has the same shape as the input; ``minval``/``step`` broadcast
    against it (unit axes are size-1).
    """

    codes: Array  # uint8
    minval: Array
    step: Array

    def dequantize(self, dtype=jnp.float32) -> Array:
        return (self.minval + self.codes.astype(jnp.float32) * self.step).astype(dtype)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.codes, self.minval, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def payload_bits_raw(self) -> int:
        """Bits of the code payload at 8 bits/code (before entropy coding)."""
        return int(self.codes.size) * 8

    @property
    def meta_bits(self) -> int:
        """Bits of affine metadata (fp16 min + fp16 step per unit)."""
        return (int(self.minval.size) + int(self.step.size)) * 16


def _affine_quantize(x: Array, minval: Array, step: Array, n_levels: int) -> Array:
    """Shared affine quantization core. Returns uint8 codes."""
    # Guard zero-range units: step==0 means the unit is constant; codes are 0
    # and dequant reproduces minval exactly.
    safe_step = jnp.where(step > 0, step, 1.0)
    q = jnp.round((x - minval) / safe_step)
    q = jnp.clip(q, 0, n_levels - 1)
    return q.astype(jnp.uint8)


def _minmax(x: Array, axes: tuple[int, ...]) -> tuple[Array, Array]:
    return jnp.min(x, axis=axes, keepdims=True), jnp.max(x, axis=axes, keepdims=True)


def quantize_k_block(x: Array, rel_scale: float, block_size: int) -> Quantized:
    """Paper's K BlockQuant.

    x: [ctx, heads, head_dim] with ctx % block_size == 0.  Units are
    (block, head, channel): min/max over the block_size tokens of each block.
    """
    ctx, heads, hd = x.shape
    if ctx % block_size != 0:
        raise ValueError(f"ctx={ctx} not a multiple of block_size={block_size}")
    xb = x.reshape(ctx // block_size, block_size, heads, hd).astype(jnp.float32)
    mn, mx = _minmax(xb, (1,))
    step = rel_scale * (mx - mn)
    codes = _affine_quantize(xb, mn, step, N_LEVELS_U8)
    return Quantized(codes=codes, minval=mn, step=step)


def quantize_k_channel(x: Array, rel_scale: float) -> Quantized:
    """KIVI-like ChannelQuant over the whole segment (per head, channel)."""
    xb = x.astype(jnp.float32)
    mn, mx = _minmax(xb, (0,))
    step = rel_scale * (mx - mn)
    codes = _affine_quantize(xb, mn, step, N_LEVELS_U8)
    return Quantized(codes=codes, minval=mn, step=step)


def quantize_v_token(x: Array, rel_scale: float) -> Quantized:
    """Paper's V TokenQuant: units are (token, head), min/max over head_dim.

    x: [ctx, heads, head_dim].
    """
    xb = x.astype(jnp.float32)
    mn, mx = _minmax(xb, (-1,))
    step = rel_scale * (mx - mn)
    codes = _affine_quantize(xb, mn, step, N_LEVELS_U8)
    return Quantized(codes=codes, minval=mn, step=step)


def kivi_quantize_k(x: Array, bits: int, group: int) -> Quantized:
    """KIVI baseline for K: channel-wise asymmetric b-bit over token groups.

    x: [ctx, heads, head_dim], ctx % group == 0. Units are (group, head,
    channel); step is (max-min)/(2^b - 1) so the full range is representable.
    """
    ctx, heads, hd = x.shape
    if ctx % group != 0:
        raise ValueError(f"ctx={ctx} not a multiple of group={group}")
    xb = x.reshape(ctx // group, group, heads, hd).astype(jnp.float32)
    mn, mx = _minmax(xb, (1,))
    n = (1 << bits)
    step = (mx - mn) / (n - 1)
    codes = _affine_quantize(xb, mn, step, n)
    return Quantized(codes=codes, minval=mn, step=step)


def kivi_quantize_v(x: Array, bits: int) -> Quantized:
    """KIVI baseline for V: token-wise asymmetric b-bit."""
    xb = x.astype(jnp.float32)
    mn, mx = _minmax(xb, (-1,))
    n = (1 << bits)
    step = (mx - mn) / (n - 1)
    codes = _affine_quantize(xb, mn, step, n)
    return Quantized(codes=codes, minval=mn, step=step)


@dataclasses.dataclass(frozen=True)
class QuantStats:
    """Diagnostics used by the benchmarks and the accuracy sweeps."""

    max_abs_err: float
    mean_abs_err: float
    clip_fraction: float
    code_entropy_bits: float  # empirical entropy of the code stream

    @staticmethod
    def measure(x: Array, q: Quantized) -> "QuantStats":
        xf = jnp.asarray(x, jnp.float32).reshape(q.codes.shape)
        err = jnp.abs(xf - q.dequantize())
        # A code is clipped iff it sits at the top level but the ideal level
        # is above it (bottom clipping cannot happen: x >= min).
        safe_step = jnp.where(q.step > 0, q.step, 1.0)
        ideal = jnp.round((xf - q.minval) / safe_step)
        clipped = (ideal > q.codes.astype(jnp.float32)).mean()
        hist = jnp.bincount(q.codes.reshape(-1).astype(jnp.int32), length=256)
        p = hist / jnp.maximum(hist.sum(), 1)
        ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(p), 0.0))
        return QuantStats(
            max_abs_err=float(err.max()),
            mean_abs_err=float(err.mean()),
            clip_fraction=float(clipped),
            code_entropy_bits=float(ent),
        )
