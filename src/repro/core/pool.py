"""Paged compressed-block pool (DESIGN.md §10).

KVComp's point is that compressed KV blocks shrink the footprint — yet the
dense cache mode still reserves ``max_seq / block_size`` ring blocks per
slot up front, so server admission is bounded by ``max_slots`` rather than
by the memory the compressed blocks actually occupy.  This module supplies
the vLLM-style alternative at *compression-block* granularity:

* one shared **arena** of physical block pages per layer (the store arrays
  of a paged ``LayerKVCache`` carry a singleton batch axis and a page axis
  of ``CacheSpec.pool_pages`` instead of a per-row ring),
* a per-row **page table** ``i32 [B, NB]`` mapping each logical ring slot
  to its physical page (``-1`` = unassigned; reads clamp, writes drop),
* a host-side **free-list allocator** (``PagedBlockPool``) whose occupancy
  is accounted in *post-compression* bytes per page, so the serving
  scheduler admits by actual memory pressure and oversubscribes slots by
  exactly the compression ratio.

Page *allocation* is host-side and page *indirection* is device-side: the
scheduler assigns pages before a row's buffer flush can land, and the jitted
decode step only ever consumes the page table (``lookup_slots`` on the write
path, ``span_view``/``to_dense`` gathers and the fused kernel's page-table
scalar-prefetch operand on the read path).  Unassigned slots are write-drop
and read-masked, so retired rows whose caches keep (garbage) decoding can
never touch pages that were freed and re-issued to another request.

Layouts stay completely unaware of paging: the logical→physical translation
happens before ``CacheLayout.write_blocks`` (``core.cache.append``) and the
gather views present a paged cache to ``decode_span``/``fetch`` as if it
were dense.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import Counter, Gauge

Array = jax.Array

STORE_FIELDS = ("k_store", "k_min", "k_step", "v_store", "v_min", "v_step")


# ---------------------------------------------------------------------------
# Byte accounting
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def page_nbytes(spec, n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16) -> int:
    """Post-compression bytes one physical page occupies for this layer.

    One page holds one compression block across all six store arrays
    (payload + quantization scales) for all ``n_kv_heads`` heads.  Computed
    exactly from the layout's own store shapes by differencing a one-block
    and a two-block allocation under ``jax.eval_shape`` (layout dummies
    cancel), so any registered layout — including user ones — is accounted
    without a bytes formula of its own.  This is the scheduler's admission
    unit and the invariant the pool's occupancy tests check against.
    """

    def nbytes(n_blocks: int) -> int:
        s = dataclasses.replace(spec, mode="dense", pool_pages=0,
                                max_seq=n_blocks * spec.block_size, window=None)
        shapes = jax.eval_shape(
            lambda: s.impl.init_store(s, 1, n_kv_heads, head_dim, dtype))
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in shapes)

    return nbytes(2) - nbytes(1)


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------


class PoolExhausted(RuntimeError):
    """Raised by ``alloc`` when the free list cannot satisfy a request."""


class PagedBlockPool:
    """Refcounted free-list allocator over ``n_pages`` physical block pages.

    Pure host-side bookkeeping (the device only ever sees page *indices*
    through the page tables).  ``page_nbytes_per_layer`` is the
    post-compression bytes one page occupies in each layer's arena; a page
    id is allocated once for ALL layers (uniform ``block_size`` means every
    layer flushes the same logical block at the same step), so occupancy is
    ``live_pages * sum(page_nbytes_per_layer)``.

    Pages are reference-counted (DESIGN.md §11): ``alloc`` hands a page out
    at refcount 1, each sharer (another row's page table, the prefix index)
    ``retain``\\ s it, and every owner drops its reference with ``release``
    — the page returns to the free list only when the count hits zero.

    Invariants (enforced, and property-tested in ``tests/test_pool.py`` /
    ``tests/test_prefix.py``): a page is never handed out twice while any
    reference is outstanding, never released below zero, and never retained
    or released without having been allocated.

    ``offset`` re-bases the page-id range to ``[offset, offset + n_pages)``
    — the sharded-serving hook (DESIGN.md §12): each data shard's pool hands
    out ids from its own slice of the global arena's page axis, so page ids
    stay globally unique across shards and a table entry identifies its
    owning shard by integer division alone.
    """

    def __init__(self, n_pages: int, page_nbytes_per_layer, offset: int = 0):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        if offset < 0:
            raise ValueError(f"page-id offset must be >= 0, got {offset}")
        self.n_pages = int(n_pages)
        self.offset = int(offset)
        self.page_nbytes_per_layer = tuple(int(b) for b in page_nbytes_per_layer)
        self._free: list[int] = list(
            range(self.offset + self.n_pages - 1, self.offset - 1, -1))
        self._live: set[int] = set()
        self._ref: dict[int, int] = {}  # page -> outstanding references
        # Typed metrics (DESIGN.md §14): standalone objects here, adopted by
        # the serving Server's MetricsRegistry under ``pool.*`` names.
        self.m_high_water = Gauge()
        self.m_alloc_pages = Counter()
        self.m_freed_pages = Counter()

    @property
    def high_water(self) -> int:
        """Most pages ever simultaneously live (gauge-backed)."""
        return int(self.m_high_water.value)

    def owns(self, page) -> bool:
        """Whether ``page`` falls in this pool's id range (live or not)."""
        return self.offset <= int(page) < self.offset + self.n_pages

    # -- core ----------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return len(self._live)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages off the free list (each at refcount 1); raises
        ``PoolExhausted`` (allocating nothing) when fewer than ``n`` are
        free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)}/{self.n_pages} free")
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        for p in pages:
            self._ref[p] = 1
        self.m_alloc_pages.inc(n)
        self.m_high_water.set_max(len(self._live))
        return pages

    def retain(self, pages) -> None:
        """Add one reference to each page (a new sharer: another row's page
        table, or a prefix-index node).  Retaining a page that is not live
        is a hard error — a freed page cannot be resurrected."""
        for p in pages:
            p = int(p)
            if p not in self._live:
                raise RuntimeError(f"retaining page {p} that is not live")
            self._ref[p] += 1

    def release(self, pages) -> list[int]:
        """Drop one reference per page; pages whose count reaches zero go
        back on the free list.  Returns the pages actually freed (the
        eviction paths use this to tell reclaimed memory from mere
        unsharing).  Releasing a page that is not live (double release, or
        never allocated) is a hard error."""
        freed: list[int] = []
        for p in pages:
            p = int(p)
            if p not in self._live:
                raise RuntimeError(f"releasing page {p} that is not live")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._live.remove(p)
                self._free.append(p)
                freed.append(p)
        self.m_freed_pages.inc(len(freed))
        return freed

    def refcount(self, page) -> int:
        """Outstanding references on one page (0 = not live)."""
        return self._ref.get(int(page), 0)

    # -- accounting ----------------------------------------------------------
    @property
    def bytes_per_page(self) -> int:
        return sum(self.page_nbytes_per_layer)

    @property
    def live_bytes(self) -> int:
        return self.live_pages * self.bytes_per_page

    @property
    def total_bytes(self) -> int:
        return self.n_pages * self.bytes_per_page

    def stats(self) -> dict:
        return {
            "pages_total": self.n_pages,
            "pages_live": self.live_pages,
            "pages_free": self.free_pages,
            "high_water_pages": self.high_water,
            "alloc_pages": self.m_alloc_pages.value,
            "freed_pages": self.m_freed_pages.value,
            "refs_total": sum(self._ref.values()),
            "pages_shared": sum(1 for c in self._ref.values() if c > 1),
            "bytes_per_page": self.bytes_per_page,
            "bytes_live": self.live_bytes,
            "bytes_total": self.total_bytes,
            "bytes_live_by_layer": [self.live_pages * b
                                    for b in self.page_nbytes_per_layer],
        }


# ---------------------------------------------------------------------------
# Device-side page indirection (all jit-safe)
# ---------------------------------------------------------------------------


def lookup_slots(page_tab: Array, slots: Array, n_blocks: int,
                 pool_pages: int) -> Array:
    """Translate logical ring slots ``[B, n]`` to physical pages.

    Preserves the write-drop convention: a slot of ``n_blocks`` (the
    cache's "this row does not flush" sentinel) or an unassigned table
    entry (``-1``) maps to ``pool_pages`` — out of range for the arena, so
    the scatter's ``mode="drop"`` discards the write.  The -1 case is what
    makes retired rows harmless: the scheduler clears their table row, and
    any flush their still-running (garbage) decode attempts lands nowhere.
    """
    phys = jnp.take_along_axis(page_tab, jnp.clip(slots, 0, n_blocks - 1), axis=1)
    return jnp.where((slots >= n_blocks) | (phys < 0), pool_pages, phys)


class _GatherView:
    """Duck-typed dense view of a paged cache's stores over one block span.

    Gathers pages ``page_tab[:, start:start+count]`` out of the shared
    arena into per-row ``[B, H, count, ...]`` arrays, so any
    ``CacheLayout.decode_span``/``fetch`` consumes paged storage unchanged
    (the layout slices from block 0 of the view).  Unassigned entries clamp
    to page 0 — the caller's ``nb_valid`` masking already excludes them.
    """

    def __init__(self, cache, start, count: int):
        pages = jax.lax.dynamic_slice_in_dim(cache.page_tab, start, count, 1)
        idx = jnp.clip(pages, 0, cache.spec.pool_pages - 1)  # [B, C]
        for f in STORE_FIELDS:
            a = getattr(cache, f)
            if a.ndim >= 4:  # layout dummies (e.g. raw's scales) pass through
                a = jnp.moveaxis(jnp.take(a[0], idx, axis=1), 1, 0)
            setattr(self, f, a)
        self.head_dim = cache.head_dim


def span_view(cache, start, count: int) -> _GatherView:
    """Dense-looking view of blocks ``[start, start+count)`` of every row."""
    return _GatherView(cache, start, count)


def to_dense(cache):
    """Materialize a paged cache as an equivalent dense ``LayerKVCache``.

    Gathers every row's pages into a private ``[B, H, NB, ...]`` ring (the
    dense twin of the spec), for consumers that want the whole store —
    ``attend_materialized``, ``api.decompress``, reconstruction tests.
    Never on the decode hot path.
    """
    from repro.core import cache as kvcache  # late: cache imports this module

    spec = cache.spec
    if not spec.paged:
        return cache
    view = _GatherView(cache, 0, spec.n_blocks)
    return kvcache.LayerKVCache(
        k_store=view.k_store, k_min=view.k_min, k_step=view.k_step,
        v_store=view.v_store, v_min=view.v_min, v_step=view.v_step,
        k_buf=cache.k_buf, v_buf=cache.v_buf,
        n_flushed=cache.n_flushed, buf_len=cache.buf_len,
        page_tab=jnp.zeros((1,), jnp.int32),
        spec=dataclasses.replace(spec, mode="dense", pool_pages=0),
    )


def from_dense(cache, pool_pages: int, pages: Array | np.ndarray | None = None):
    """Re-house a dense cache's blocks in a fresh paged arena.

    ``pages``: i32 ``[B, NB]`` physical page assignment (entries must be
    distinct where >= 0; ``-1`` leaves a slot unassigned).  Defaults to the
    row-major identity ``page(b, i) = b * NB + i``.  This is the
    test/benchmark bridge: build any cache state with the dense machinery,
    scatter it into a (permuted) page set, and check every decode path
    agrees on the paged storage.
    """
    from repro.core import cache as kvcache  # late: cache imports this module

    spec = cache.spec
    if spec.paged:
        raise ValueError("from_dense takes a dense cache")
    B, NB = cache.batch, spec.n_blocks
    if pages is None:
        if pool_pages < B * NB:
            raise ValueError(f"identity mapping needs {B * NB} pages, "
                             f"pool has {pool_pages}")
        pages = np.arange(B * NB, dtype=np.int32).reshape(B, NB)
    pages = jnp.asarray(pages, jnp.int32)
    pspec = dataclasses.replace(spec, mode="paged", pool_pages=pool_pages)
    paged = kvcache.init_layer_cache(pspec, B, cache.k_buf.shape[1],
                                     cache.head_dim, cache.k_buf.dtype)
    # Unassigned (-1) must not wrap to the last page: drop applies after
    # index normalization, so rewrite the sentinel out of range.
    flat = jnp.where(pages < 0, pool_pages, pages).reshape(-1)
    out = {}
    for f in STORE_FIELDS:
        arena, dense = getattr(paged, f), getattr(cache, f)
        if dense.ndim < 4:  # layout dummy — shared as-is
            out[f] = dense
            continue
        # [B, H, NB, ...] -> [H, B*NB, ...] then scatter into arena pages.
        vals = jnp.moveaxis(dense, 1, 0).reshape(
            dense.shape[1], B * NB, *dense.shape[3:]).astype(arena.dtype)
        out[f] = arena[0].at[:, flat].set(vals, mode="drop")[None]
    return kvcache.LayerKVCache(
        **out, k_buf=cache.k_buf, v_buf=cache.v_buf,
        n_flushed=cache.n_flushed, buf_len=cache.buf_len,
        page_tab=pages, spec=pspec)


# ---------------------------------------------------------------------------
# Scheduler-facing splice / page-table maintenance (jit-safe; `row` traced)
# ---------------------------------------------------------------------------


def _lead(cache) -> int:
    """0 for a bare LayerKVCache, 1 when stacked over layers (scan state)."""
    return cache.n_flushed.ndim - 1


def splice_row(dst, src, row, pages: Array):
    """Admission splice: land a solo dense prefill in row ``row`` of a paged
    batched cache (the paged counterpart of ``model.insert_decode_row``).

    ``dst`` is paged (possibly layer-stacked: every leaf has a leading L
    axis), ``src`` is the batch=1 *dense* cache the solo prefill produced,
    ``pages`` is i32 ``[NB]``: the physical page for logical block ``i``
    (``-1`` for blocks the prompt did not fill — those writes drop).  Solo
    prefill never wraps the ring (prompt <= max_seq), so dense slot ``i``
    IS logical block ``i`` and the splice is one page-scatter per store.
    """
    lead = _lead(dst)
    pax = lead + 2  # stores: [L?, 1(arena), H, page, ...]

    # ``mode="drop"`` only discards indices that stay out of bounds AFTER
    # normalization — a raw -1 would wrap to the last page — so the empty
    # slots' sentinel is rewritten to the (always out-of-range) page count.
    pages_ix = jnp.where(pages < 0, dst.spec.pool_pages, pages)

    def store_field(d, s):
        if d.ndim < pax + 2:  # layout dummy scales
            return d
        d0 = jnp.moveaxis(d, pax, 0)  # [P, L?, 1, H, ...]
        s0 = jnp.moveaxis(s, pax, 0)  # [NB, L?, 1, H, ...]
        return jnp.moveaxis(d0.at[pages_ix].set(s0.astype(d.dtype), mode="drop"),
                            0, pax)

    def row_field(d, s):  # batch axis at `lead` for buffers and length vectors
        return jax.lax.dynamic_update_slice_in_dim(d, s.astype(d.dtype), row, lead)

    pt0 = jnp.moveaxis(dst.page_tab, lead, 0)  # [B, L?, NB]
    ptv = jnp.broadcast_to(pages, pt0.shape[1:]) if lead else pages
    page_tab = jnp.moveaxis(pt0.at[row].set(ptv), 0, lead)

    return type(dst)(
        **{f: store_field(getattr(dst, f), getattr(src, f)) for f in STORE_FIELDS},
        k_buf=row_field(dst.k_buf, src.k_buf),
        v_buf=row_field(dst.v_buf, src.v_buf),
        n_flushed=row_field(dst.n_flushed, src.n_flushed),
        buf_len=row_field(dst.buf_len, src.buf_len),
        page_tab=page_tab, spec=dst.spec)


def chunk_view(cache, pages: Array, pos0):
    """Batch-1 view of one row's chunked prefill, writing the live arena
    in place (DESIGN.md §13).

    ``cache`` is the live *paged* batched cache (possibly layer-stacked),
    ``pages`` is i32 ``[NB]`` — the physical page of logical block ``i``
    for the blocks this row's prefill has flushed or is about to flush
    (``-1`` beyond) — and ``pos0`` is the block-aligned token position the
    next chunk starts at.  The view SHARES the arena store arrays: a chunk
    appended through it (``core.cache.append_chunk`` →
    ``CacheLayout.write_blocks``) quantizes/packs straight into the pooled
    pages, so the prompt's KV never exists uncompressed beyond one
    ``block_size`` buffer.  Buffers start empty (chunks are block-aligned
    by construction) and the page table is the single row ``pages`` — the
    live per-row tables are untouched, so the row stays write-dropped for
    the concurrently decoding batch until ``install_row``.
    """
    lead = _lead(cache)
    T = cache.spec.block_size

    def row0_zeros(a):  # fresh empty buffer shaped like one row
        return jnp.zeros_like(jax.lax.slice_in_dim(a, 0, 1, axis=lead))

    nf = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32) // T,
                          (*cache.n_flushed.shape[:lead], 1))
    pt = jnp.broadcast_to(pages, (*cache.n_flushed.shape[:lead], 1,
                                  pages.shape[0]))
    return dataclasses.replace(
        cache, k_buf=row0_zeros(cache.k_buf), v_buf=row0_zeros(cache.v_buf),
        n_flushed=nf, buf_len=jnp.zeros_like(nf), page_tab=pt)


def adopt_stores(dst, src):
    """Fold a ``chunk_view``'s updated arena stores back into the live
    batched cache between chunks (buffers, lengths and page tables keep the
    live batch's values — only the shared arena advanced)."""
    return dataclasses.replace(
        dst, **{f: getattr(src, f) for f in STORE_FIELDS})


def install_row(dst, src, row, pages: Array):
    """Land a finished chunked prefill in row ``row`` of the live cache.

    ``src`` is the final ``chunk_view`` state: its stores ARE the live
    arena after the last flush (adopted wholesale — no scatter, unlike
    ``splice_row``'s dense-to-paged copy), its batch-1 buffers hold the
    prompt's sub-block tail, and ``pages`` is the row's page table.  Row
    fields splice at the batch axis; the page-table row flips from
    write-drop (-1) to live in the same update, so the very next decode
    step attends the prefilled blocks.
    """
    lead = _lead(dst)

    def row_field(d, s):  # batch axis at `lead` for buffers and length vectors
        return jax.lax.dynamic_update_slice_in_dim(d, s.astype(d.dtype), row, lead)

    pt0 = jnp.moveaxis(dst.page_tab, lead, 0)  # [B, L?, NB]
    ptv = jnp.broadcast_to(pages, pt0.shape[1:]) if lead else pages
    page_tab = jnp.moveaxis(pt0.at[row].set(ptv), 0, lead)

    return type(dst)(
        **{f: getattr(src, f) for f in STORE_FIELDS},
        k_buf=row_field(dst.k_buf, src.k_buf),
        v_buf=row_field(dst.v_buf, src.v_buf),
        n_flushed=row_field(dst.n_flushed, src.n_flushed),
        buf_len=row_field(dst.buf_len, src.buf_len),
        page_tab=page_tab, spec=dst.spec)


def gather_pages(cache, pages: Array, n_flushed: Array):
    """Prefix-hit seed: materialize cached arena pages as a batch-1 *dense*
    cache positioned at a block boundary (DESIGN.md §11).

    ``cache`` is the live paged cache (possibly layer-stacked), ``pages`` is
    i32 ``[NB]`` — the physical page holding logical block ``i`` for the
    first ``n_flushed`` blocks (``-1`` padding beyond; those slots gather
    garbage that the ``n_flushed`` mask keeps invisible).  The result is
    exactly the state a solo block-chunked prefill of those ``n_flushed``
    blocks would have produced: stores gathered bit-for-bit from the arena,
    raw buffer empty, ``buf_len = 0`` — so chunked prefill resumes from
    block ``n_flushed`` as if it had started from token 0.  ``n_flushed``
    may be traced (one compilation serves every hit length).
    """
    from repro.core import cache as kvcache  # late: cache imports this module

    lead = _lead(cache)
    pax = lead + 2  # stores: [L?, 1(arena), H, page, ...]
    idx = jnp.clip(pages, 0, cache.spec.pool_pages - 1)

    def store_field(a):
        if a.ndim < pax + 2:  # layout dummy scales pass through
            return a
        return jnp.take(a, idx, axis=pax)

    def row0_zeros(a):  # fresh empty buffer shaped like one row
        return jnp.zeros_like(jax.lax.slice_in_dim(a, 0, 1, axis=lead))

    nf = jnp.broadcast_to(jnp.asarray(n_flushed, jnp.int32),
                          (*cache.n_flushed.shape[:lead], 1))
    return kvcache.LayerKVCache(
        **{f: store_field(getattr(cache, f)) for f in STORE_FIELDS},
        k_buf=row0_zeros(cache.k_buf), v_buf=row0_zeros(cache.v_buf),
        n_flushed=nf, buf_len=jnp.zeros_like(nf),
        page_tab=jnp.zeros((*cache.n_flushed.shape[:lead], 1), jnp.int32),
        spec=dataclasses.replace(cache.spec, mode="dense", pool_pages=0))


def assign_pages(cache, rows: Array, slots: Array, pages: Array):
    """Point ``page_tab[rows[i], slots[i]] = pages[i]`` (vectorized, padded
    entries use ``rows < 0`` and drop).  The scheduler calls this just
    before the decode step that will flush those blocks."""
    lead = _lead(cache)
    pt = cache.page_tab
    # Negative padding rows must stay out of bounds (drop happens after
    # index normalization, so -1 would wrap to the last slot's row).
    rows = jnp.where(rows < 0, pt.shape[lead], rows)
    if lead:
        pt = pt.at[:, rows, slots].set(pages[None], mode="drop")
    else:
        pt = pt.at[rows, slots].set(pages, mode="drop")
    return dataclasses.replace(cache, page_tab=pt)


def clear_row(cache, row):
    """Unassign every page of one row (retire / preempt): subsequent flushes
    from that slot's garbage decode drop, reads stay masked by nb_valid."""
    lead = _lead(cache)
    pt0 = jnp.moveaxis(cache.page_tab, lead, 0)
    pt = jnp.moveaxis(pt0.at[row].set(-1), 0, lead)
    return dataclasses.replace(cache, page_tab=pt)
