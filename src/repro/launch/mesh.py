"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.

Mesh creation goes through ``repro.distributed.sharding.make_mesh``, which
hides the jax-version split around ``jax.sharding.AxisType``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model); the pod axis is the
    DCN-connected outermost axis (pure DP + compressed grad all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(max_devices: int | None = None):
    """Elastic small mesh over whatever devices exist (tests, local runs)."""
    n = len(jax.devices()) if max_devices is None else min(max_devices, len(jax.devices()))
    # favor a model axis that divides n
    for m in (8, 4, 2, 1):
        if n % m == 0:
            return make_mesh((n // m, m), ("data", "model"))
    raise RuntimeError("no devices")


def make_serve_mesh(spec: str | None):
    """Build the serving mesh from a ``--mesh dp,tp`` CLI spec.

    ``dp`` shards decode slots / page tables / the paged arena's page axis
    ("data"); ``tp`` shards KV heads inside attention ("model").  Returns
    None for an empty spec or a 1x1 mesh — a single-device mesh serves
    identically to the unsharded path, so the Server treats them the same
    (DESIGN.md §12).  Raises with the ``XLA_FLAGS`` recipe when the host
    exposes fewer devices than ``dp * tp`` asks for.
    """
    if not spec:
        return None
    try:
        dp, tp = (int(p) for p in spec.split(","))
    except ValueError:
        raise ValueError(
            f"--mesh wants 'dp,tp' (two integers), got {spec!r}") from None
    if dp < 1 or tp < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    if dp * tp == 1:
        return None
    devs = jax.devices()
    if dp * tp > len(devs):
        raise RuntimeError(
            f"--mesh {spec} needs {dp * tp} devices but only {len(devs)} "
            "exist; on CPU export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={dp * tp} "
            "before starting python (it must precede jax initialization)")
    return jax.sharding.Mesh(
        np.asarray(devs[: dp * tp]).reshape(dp, tp), ("data", "model"))
