"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.

Mesh creation goes through ``repro.distributed.sharding.make_mesh``, which
hides the jax-version split around ``jax.sharding.AxisType``.
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model); the pod axis is the
    DCN-connected outermost axis (pure DP + compressed grad all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(max_devices: int | None = None):
    """Elastic small mesh over whatever devices exist (tests, local runs)."""
    n = len(jax.devices()) if max_devices is None else min(max_devices, len(jax.devices()))
    # favor a model axis that divides n
    for m in (8, 4, 2, 1):
        if n % m == 0:
            return make_mesh((n // m, m), ("data", "model"))
    raise RuntimeError("no devices")
