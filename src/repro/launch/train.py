"""Training launcher.

CPU-runnable by default (reduced config, tiny mesh); the production path
(--production) builds the full config against the 16×16 or 2×16×16 mesh —
on this container that is only lowerable (see dryrun.py), on a real fleet it
is the same code path.

Examples:
    python -m repro.launch.train --arch qwen3_1_7b --steps 50
    python -m repro.launch.train --arch mamba2_1_3b --steps 30 --resume
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.data.pipeline import SyntheticCorpus, TextCorpus
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import registry
from repro.optim import adamw
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (default on CPU)")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="cross-pod error-feedback int8 all-reduce")
    ap.add_argument("--data", choices=["text", "synthetic"], default="text")
    args = ap.parse_args()

    if args.production:
        cfg = registry.get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        cfg = registry.get_smoke_config(args.arch)
        mesh = make_host_mesh()

    if args.data == "text" and cfg.input_mode == "tokens":
        cfg = dataclasses.replace(cfg, vocab_size=max(cfg.vocab_size, 256))
        data = TextCorpus(seq_len=args.seq, global_batch=args.batch)
        data.vocab_size = cfg.vocab_size
    else:
        data = SyntheticCorpus(seq_len=args.seq, global_batch=args.batch,
                               vocab_size=cfg.vocab_size)

    scfg = step_lib.TrainStepConfig(
        remat=True,
        microbatches=args.microbatches,
        q_chunk=min(512, args.seq), kv_chunk=min(512, args.seq),
        cross_pod_grad_compress=args.grad_compress,
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps),
    )
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_every=5)
    trainer = Trainer(cfg, mesh, scfg, tcfg, data)
    trainer.install_signal_handlers()
    if args.resume:
        resumed = trainer.maybe_resume()
        print(f"resume: {'ok, from step ' + str(trainer.start_step) if resumed else 'no checkpoint'}")
    summary = trainer.run()
    print("summary:", summary)


if __name__ == "__main__":
    main()
