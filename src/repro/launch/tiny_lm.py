"""The shared tiny byte-level LM (DESIGN.md §6 accuracy proxy).

One tiny LM trained on real text is the CPU-scale stand-in for the paper's
Llama2/Ministral experiments: `benchmarks/` harvests its KV statistics and
`examples/serve_compressed.py` serves it end to end.  Both entry points
share THIS config and THIS checkpoint cache (``artifacts/tiny_lm``), so the
definition lives once under ``src/repro`` — a drifted duplicate would make
the second entry point restore a shape-mismatched checkpoint.
"""

from __future__ import annotations

from pathlib import Path

import jax

from repro.checkpoint import store
from repro.data.pipeline import TextCorpus
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig

# repo_root/artifacts/tiny_lm (this file lives at src/repro/launch/).
CKPT = Path(__file__).resolve().parents[3] / "artifacts" / "tiny_lm"

TINY = ModelConfig(
    name="tiny-byte-lm", family="dense", n_layers=4, d_model=256,
    vocab_size=256, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
    cache_block=32, rel_scale_k=0.05, rel_scale_v=0.15)

SEQ = 128
STEPS = 300


def get_tiny_lm(steps: int = STEPS, force: bool = False):
    """Train (or checkpoint-load) the tiny LM. Returns (cfg, params, corpus)."""
    data = TextCorpus(seq_len=SEQ, global_batch=8, max_bytes=2 << 20)
    params_shape, _ = step_lib.shapes_and_axes(TINY)
    if not force and store.latest_step(CKPT) is not None:
        params, _ = store.restore(CKPT, params_shape)
        return TINY, params, data
    scfg = step_lib.TrainStepConfig(
        remat=False, q_chunk=SEQ, kv_chunk=SEQ,
        opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps))
    trainer = Trainer(TINY, make_host_mesh(), scfg,
                      TrainerConfig(total_steps=steps, ckpt_every=0,
                                    log_every=50, ckpt_dir=str(CKPT / "_train")),
                      data)
    out = trainer.run()
    print(f"[tiny_lm] trained: {out['final_step']} steps, "
          f"loss {out['last_loss']:.3f}")
    params = jax.tree.map(lambda x: x, trainer.state[0])
    store.save(CKPT, steps, params, {"loss": out["last_loss"]})
    return TINY, params, data
