"""Serving launcher: batched generation with the compressed KV cache.

    python -m repro.launch.serve --arch yi_6b --layout packed --requests 8
    python -m repro.launch.serve --arch yi_6b --layout raw   # baseline

Prints per-layout cache memory + throughput so the paper's memory-reduction
and overhead story is visible end to end on CPU.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.models import model as M
from repro.models import registry
from repro.serve.engine import Engine, EngineConfig, Request, cache_memory_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    from repro import api

    ap.add_argument("--layout", default="packed",
                    choices=list(api.available_layouts()))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, cache_layout=args.layout)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_seq=args.max_seq, bucket=32,
                                           max_batch=args.requests))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    results = eng.generate(reqs)
    tput = sum(args.new_tokens / r.gen_s for r in results if r.gen_s > 0)
    # memory report from a live prefilled state
    logits, state = M.prefill(params, cfg, {"tokens": np.stack([r.prompt for r in reqs])},
                              args.max_seq)
    rep = cache_memory_report(cfg, state)
    print(f"layout={args.layout} requests={len(results)} "
          f"decode_throughput={tput:.1f} tok/s "
          f"kv_cache_bytes={rep['kv_bytes']:,}")
    for i, r in enumerate(results[:3]):
        print(f"  req{i}: prompt_len={r.prompt_len} tokens={r.tokens[:8].tolist()}…")


if __name__ == "__main__":
    main()
