"""Serving launcher: continuous-batching generation with the compressed KV
cache.

    python -m repro.launch.serve --arch yi_6b --layout packed --requests 8
    python -m repro.launch.serve --arch yi_6b --layout raw   # baseline

Requests get heterogeneous prompt lengths and token budgets and are pushed
through the ``api.serve`` Server — slots admit, decode at per-row positions,
retire, and are reused mid-flight.  Prints per-request results plus the
per-layout cache memory, so the paper's memory-reduction and overhead story
is visible end to end on CPU.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import api
from repro.launch.mesh import make_serve_mesh
from repro.models import model as M
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--layout", default="packed",
                    choices=list(api.available_layouts()))
    ap.add_argument("--backend", default=None,
                    choices=list(api.available_backends()) + ["auto"],
                    help="decode-attention backend (default: the model "
                         "config's attn_backend — auto: fused kernel on TPU, "
                         "blockwise scan elsewhere)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--cache-mode", default="dense", choices=["dense", "paged"],
                    help="paged: pool compressed blocks in a shared arena "
                         "and admit by memory pressure (DESIGN.md §10)")
    ap.add_argument("--pool-bytes", type=int, default=None,
                    help="paged: byte budget for the block pool (default: "
                         "the dense-equivalent footprint of --max-slots)")
    ap.add_argument("--prefix-cache", default="off",
                    choices=["off", "on", "noshare"],
                    help="paged: share block-aligned prompt prefixes through "
                         "the refcounted page index (DESIGN.md §11); noshare "
                         "runs the same chunked admission without sharing")
    ap.add_argument("--span-tokens", type=int, default=None,
                    help="blockwise-scan span width in tokens (mirrors "
                         "REPRO_BLOCKWISE_SPAN_TOKENS; default: model config)")
    ap.add_argument("--unroll-max", type=int, default=None,
                    help="max spans unrolled before the scan falls back to "
                         "lax.scan (mirrors REPRO_BLOCKWISE_UNROLL_MAX; "
                         "default: model config)")
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "solo"],
                    help="chunked (default): splice admission prefills "
                         "between decode steps in --prefill-chunk budgets "
                         "(DESIGN.md §13); solo: drain the whole prompt at "
                         "admission, stalling live decoders")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="per-step chunked-prefill token budget; must be a "
                         "positive multiple of the cache block_size "
                         "(default: 8 blocks)")
    ap.add_argument("--mesh", default=None,
                    help="dp,tp serving mesh (DESIGN.md §12), e.g. 2,2 — "
                         "shards slots and the paged arena over dp and KV "
                         "heads over tp; on CPU export "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                         "first")
    ap.add_argument("--trace", default="off",
                    choices=["off", "events", "full"],
                    help="scheduler event trace (DESIGN.md §14): events "
                         "records every scheduling decision in a ring "
                         "buffer, full adds decode dispatch spans; off "
                         "keeps the hot path event-free")
    ap.add_argument("--trace-out", default=None,
                    help="write the event trace as Chrome trace-event JSON "
                         "(open in Perfetto; needs --trace events|full)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics snapshot as JSON here, plus a "
                         ".prom Prometheus-text sibling")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request wall-clock deadline in seconds "
                         "(DESIGN.md §15); requests past it retire with "
                         "finish_reason='deadline'")
    ap.add_argument("--max-requeues", type=int, default=32,
                    help="preemption/requeue budget per request; over "
                         "budget a (non-oldest) request fails in isolation "
                         "instead of requeueing (DESIGN.md §15)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bound the admission queue; submits past the "
                         "bound apply --backpressure")
    ap.add_argument("--backpressure", default="reject",
                    choices=["reject", "block"],
                    help="full-queue policy: reject raises, block drives "
                         "the server until the queue drains")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="cross-check pool/page-table/prefix-index "
                         "invariants every N steps (0 = off; DESIGN.md §15)")
    ap.add_argument("--fault", action="append", default=[],
                    metavar="SITE[:PROB]",
                    help="inject deterministic faults at a named site "
                         "(repeatable; prob defaults to 1.0), e.g. "
                         "--fault reclaim_sweep:0.05 — sites: "
                         "pool_alloc, reclaim_sweep, prefix_evict, "
                         "prefix_insert, chunk_prefill, decode_dispatch")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --fault schedule (replayable)")
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, cache_layout=args.layout)
    if args.span_tokens is not None:
        cfg = dataclasses.replace(cfg, cache_span_tokens=args.span_tokens)
    if args.unroll_max is not None:
        cfg = dataclasses.replace(cfg, cache_unroll_max=args.unroll_max)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_serve_mesh(args.mesh) if args.mesh else None
    faults = None
    if args.fault:
        from repro.serve.faults import FaultPlan
        rates = {}
        for spec in args.fault:
            site, _, prob = spec.partition(":")
            rates[site] = float(prob) if prob else 1.0
        faults = FaultPlan(seed=args.fault_seed, rates=rates)
    server = api.serve(cfg, params, max_slots=args.max_slots,
                       max_seq=args.max_seq, attn_backend=args.backend,
                       cache_mode=args.cache_mode,
                       pool_hbm_bytes=args.pool_bytes,
                       prefix_cache=args.prefix_cache,
                       prefill_mode=args.prefill_mode,
                       prefill_chunk_tokens=args.prefill_chunk,
                       mesh=mesh, trace=args.trace,
                       max_requeues=args.max_requeues,
                       max_pending=args.max_pending,
                       backpressure=args.backpressure,
                       default_deadline_s=args.deadline,
                       faults=faults,
                       audit_every=args.audit_every)
    rng = np.random.default_rng(0)
    # With the prefix cache enabled, requests share a system-prompt prefix
    # (half of --prompt-len) so the printed hit-rate exercises real reuse.
    shared = (rng.integers(0, cfg.vocab_size, args.prompt_len // 2)
              .astype(np.int32) if args.prefix_cache != "off" else
              np.zeros(0, np.int32))
    handles = []
    for i in range(args.requests):
        # heterogeneous workload: prompts from half to full --prompt-len,
        # budgets from half to full --new-tokens
        plen = max(4, args.prompt_len - (i * args.prompt_len // 2) // max(args.requests - 1, 1))
        n_new = max(2, args.new_tokens - (i * args.new_tokens // 2) // max(args.requests - 1, 1))
        tail = rng.integers(0, cfg.vocab_size,
                            max(plen - len(shared), 1)).astype(np.int32)
        prompt = np.concatenate([shared, tail])
        handles.append(server.submit(api.Request(prompt=prompt,
                                                 max_new_tokens=n_new)))
    t0 = time.monotonic()
    try:
        server.run()
    except KeyboardInterrupt:
        # Ctrl-C must not lose the run's telemetry: print the final
        # snapshot and run the shutdown exports before exiting with the
        # conventional interrupt status.
        wall = time.monotonic() - t0
        print(f"\ninterrupted after {wall:.1f}s: active={server.active} "
              f"prefilling={server.prefilling} pending={server.pending}")
        print(api.obs.format_snapshot(server.stats()))
        server.shutdown(metrics_out=args.metrics_out,
                        trace_out=args.trace_out)
        raise SystemExit(130)
    wall = time.monotonic() - t0
    results = [h.result() for h in handles]
    total = sum(len(r.tokens) for r in results)
    rep = server.memory_report()
    print(f"layout={args.layout} mode={args.cache_mode} "
          f"requests={len(results)} slots={args.max_slots} tokens={total} "
          f"throughput={total / wall:.1f} tok/s "
          f"kv_cache_bytes={rep['kv_bytes']:,}")
    # One schema, one printer: stats() is the registry snapshot and
    # format_snapshot is the shared renderer (DESIGN.md §14) — the old
    # hand-rolled section printers drifted between launchers.
    print(api.obs.format_snapshot(server.stats()))
    if args.metrics_out or args.trace_out:
        server.shutdown(metrics_out=args.metrics_out,
                        trace_out=args.trace_out)
    for i, r in enumerate(results[:4]):
        # ttft_s is None for token-less (failed/cancelled/expired) requests
        ttft = f"{r.ttft_s * 1e3:.0f}ms" if r.ttft_s is not None else "-"
        print(f"  req{i}: prompt_len={r.prompt_len} n_tokens={len(r.tokens)} "
              f"queue={r.queue_wait_s * 1e3:.0f}ms "
              f"ttft={ttft} "
              f"prefill={r.prefill_s * 1e3:.0f}ms gen={r.gen_s * 1e3:.0f}ms "
              f"finish={r.finish_reason} tokens={r.tokens[:8].tolist()}…")


if __name__ == "__main__":
    main()
