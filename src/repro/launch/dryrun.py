import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and derive the roofline terms.

Per cell this produces two kinds of compiles:

1. **full** — the real step (scan-over-layers, flash-chunked attention, full
   depth) is lowered and compiled; success is the deliverable gate, and
   ``memory_analysis()`` proves per-device fit.

2. **analysis** — XLA's CPU cost model counts loop bodies ONCE (verified in
   EXPERIMENTS.md §Dry-run notes), so FLOPs/bytes/collective bytes come from
   two loop-free compiles at reduced depth (layers unrolled, attention/SSD
   chunk scans unrolled) and are linearly extrapolated:
       per_layer = c(d2) − c(d1);  total = c(d1) + (L − d1)·per_layer.
   Collective bytes are parsed from the post-SPMD HLO (all-gather /
   all-reduce / reduce-scatter / all-to-all / collective-permute operand
   sizes).

Usage:
    python -m repro.launch.dryrun --arch yi_6b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all          # every cell, both meshes
    python -m repro.launch.dryrun --all --mesh multipod --no-analysis
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import registry
from repro.models.config import ModelConfig
from repro.distributed import sharding as shd
from repro.train import step as step_lib

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# TPU v5e hardware constants (per chip).
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "u16": 2,
               "s16": 2, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    info = SHAPES[shape_name]
    if info["kind"] == "decode" and cfg.encoder_only:
        return "encoder-only: no autoregressive decode"
    if shape_name == "long_500k":
        if cfg.encoder_only:
            return "encoder-only: no decode"
        if not cfg.supports_long_context_decode:
            return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    if info["kind"] in ("train", "prefill"):
        if cfg.input_mode == "tokens":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        else:
            specs = {"embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        if info["kind"] == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
    # decode: one new token against a seq-long cache
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, cfg.d_model), jnp.bfloat16)}


# ---------------------------------------------------------------------------
# cell builders: (fn, example_args, in_shardings)
# ---------------------------------------------------------------------------


def build_train_cell(cfg: ModelConfig, shape_name: str, mesh, *,
                     q_chunk=2048, kv_chunk=2048, unroll=False, microbatches=8):
    scfg = step_lib.TrainStepConfig(
        remat=True, q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll,
        microbatches=microbatches)
    bspecs = input_specs(cfg, shape_name)
    step, state_shapes, in_sh, out_sh = step_lib.build_train_artifacts(
        cfg, mesh, scfg, bspecs)
    state_shapes = tuple(state_shapes[:2]) + (None,)
    in_sh = ((in_sh[0][0], in_sh[0][1], None), in_sh[1])
    return step, (state_shapes, bspecs), (in_sh, out_sh)


def build_prefill_cell(cfg: ModelConfig, shape_name: str, mesh, *,
                       q_chunk=2048, kv_chunk=2048, unroll=False):
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    bspecs = input_specs(cfg, shape_name)
    rules = shd.serve_rules(cfg, mesh)
    shd.set_ambient_mesh(mesh)
    pshapes, axes = step_lib.shapes_and_axes(cfg)
    pshard = shd.make_param_shardings(axes, pshapes, rules, mesh)
    bshard = {k: shd.batch_sharding(mesh, v) for k, v in bspecs.items()}

    if cfg.encoder_only:
        def fn(params, batch):
            logits, _ = M.forward(params, cfg, batch,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
            return logits
    else:
        def fn(params, batch):
            logits, state = M.prefill(params, cfg, batch, max_seq=S,
                                      q_chunk=q_chunk, kv_chunk=kv_chunk,
                                      unroll=unroll)
            return logits[:, -1], state

    return fn, ((pshapes, bspecs),), ((pshard, bshard), None)


def build_decode_cell(cfg: ModelConfig, shape_name: str, mesh, *, unroll=False, **_kw):
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    bspecs = input_specs(cfg, shape_name)
    rules = shd.serve_rules(cfg, mesh)
    shd.set_ambient_mesh(mesh)
    pshapes, axes = step_lib.shapes_and_axes(cfg)
    pshard = shd.make_param_shardings(axes, pshapes, rules, mesh)
    state_shapes = jax.eval_shape(lambda: M.init_decode_state(cfg, B, S))
    sshard = shd.cache_shardings(state_shapes, mesh)
    tok_sh = shd.batch_sharding(mesh, bspecs["tokens"])
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, tokens, position, state):
        return M.decode_step(params, cfg, tokens, position, state, unroll=unroll)

    args = (pshapes, bspecs["tokens"], pos, state_shapes)
    in_sh = (pshard, tok_sh, shd.replicated(mesh), sshard)
    return fn, (args,), (in_sh, None)


def build_cell(cfg, shape_name, mesh, **kw):
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        fn, (st, bs), (in_sh, out_sh) = build_train_cell(cfg, shape_name, mesh, **kw)
        return fn, (st, bs), (in_sh, out_sh)
    if kind == "prefill":
        fn, (args,), sh = build_prefill_cell(cfg, shape_name, mesh, **kw)
        return fn, args, sh
    fn, (args,), sh = build_decode_cell(cfg, shape_name, mesh, **kw)
    return fn, args, sh


# ---------------------------------------------------------------------------
# HLO accounting
# ---------------------------------------------------------------------------


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(tok_dtype, 4)


def parse_collective_bytes(hlo: str) -> dict:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text."""
    out = {c: 0 for c in COLLECTIVES}
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = ", ls)
        if not m:
            continue
        rhs = ls[m.end():]
        opm = re.match(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*) ([a-z\-]+)\(", rhs)
        if not opm or opm.group(1) not in COLLECTIVES:
            continue
        op = opm.group(1)
        # operand list inside the call parens: count operand shapes
        call = rhs[rhs.index("(") + 1:]
        # operands are %name references; their shapes appear in the def lines,
        # but HLO also inlines shapes for constants. Use the op RESULT shape
        # as the moved-bytes proxy for single-operand collectives (operand
        # size == result size for all-reduce/permute/all-to-all; for
        # all-gather the operand is result/axis, for reduce-scatter the
        # operand is result*axis — we take max(operand,result) conservatism
        # by recording the RESULT bytes and correcting all-gather below).
        shapes = _SHAPE_RE.findall(rhs[: rhs.index("(")])
        nbytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        out[op] += nbytes
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def cost_numbers(compiled) -> dict:
    ca = compiled.cost_analysis()
    # jax < 0.5 returns a one-dict-per-device LIST from some executables
    # (donated-argument decode steps among them); normalize to the dict.
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def memory_numbers(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }


# ---------------------------------------------------------------------------
# per-cell driver
# ---------------------------------------------------------------------------


def lower_compile(fn, args, in_sh, out_sh, donate=None):
    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def analysis_depths(cfg: ModelConfig) -> tuple[ModelConfig, ModelConfig, int, int]:
    """Two reduced-depth configs + their depths in 'units' (layers/periods)."""
    if cfg.family == "hybrid":
        p = cfg.hybrid_period
        c1 = dataclasses.replace(cfg, n_layers=1 * p)
        c2 = dataclasses.replace(cfg, n_layers=2 * p)
        return c1, c2, 1, 2
    c1 = dataclasses.replace(cfg, n_layers=1)
    c2 = dataclasses.replace(cfg, n_layers=2)
    return c1, c2, 1, 2


def full_units(cfg: ModelConfig) -> float:
    """Depth in the units used by analysis_depths (layers, or periods with
    the tail counted as a mamba-share fraction of a period)."""
    if cfg.family == "hybrid":
        n_periods, ppm, tail = M._hybrid_counts(cfg)
        return n_periods + (tail / ppm) * (ppm / cfg.hybrid_period)  # ≈ mamba share
    return float(cfg.n_layers)


def run_analysis(cfg: ModelConfig, shape_name: str, mesh) -> dict:
    """Loop-free reduced-depth compiles -> extrapolated flops/bytes/collectives."""
    kind = SHAPES[shape_name]["kind"]
    c1, c2, d1, d2 = analysis_depths(cfg)
    # chunks sized so the triangular causal schedule is visible in the
    # unrolled HLO (train 4k -> nq=2; prefill 32k -> nq=4) while keeping the
    # number of unrolled attention bodies bounded
    ck = 2048 if SHAPES[shape_name]["seq"] <= 4096 else 8192
    kw = dict(q_chunk=ck, kv_chunk=ck, unroll=True)
    if cfg.ssm_state:
        c1 = dataclasses.replace(c1, ssm_chunk=2048)
        c2 = dataclasses.replace(c2, ssm_chunk=2048)

    def one(c):
        if kind == "train":
            fn, (st, bs), (in_sh, out_sh) = build_train_cell(
                c, shape_name, mesh, microbatches=1, **kw)
            compiled, _ = lower_compile(fn, (st, bs), in_sh, out_sh)
        elif kind == "prefill":
            fn, args, (in_sh, out_sh) = build_cell(c, shape_name, mesh, **kw)
            compiled, _ = lower_compile(fn, args, in_sh, out_sh)
        else:
            fn, args, (in_sh, out_sh) = build_cell(c, shape_name, mesh, unroll=True)
            compiled, _ = lower_compile(fn, args, in_sh, out_sh)
        nums = cost_numbers(compiled)
        nums["collectives"] = parse_collective_bytes(compiled.as_text())
        return nums

    n1, n2 = one(c1), one(c2)
    L = full_units(cfg)

    def extrap(a, b):
        per = (b - a) / (d2 - d1)
        return max(a + (L - d1) * per, 0.0)

    coll = {}
    for k in n1["collectives"]:
        coll[k] = extrap(n1["collectives"][k], n2["collectives"][k])
    return {
        "flops": extrap(n1["flops"], n2["flops"]),
        "bytes": extrap(n1["bytes"], n2["bytes"]),
        "collectives": coll,
        "depth_points": {str(d1): n1, str(d2): n2},
    }


def _serve_shards(chips: int, batch: int) -> tuple[int, int, int]:
    """(model_n, data_n, batch_shards) of the serve mesh: TP over a 16-wide
    model axis, batch sharded over the data axes when it divides evenly."""
    model_n = 16  # single-pod mesh model axis
    data_n = max(chips // model_n, 1)
    batch_shards = data_n if batch % data_n == 0 else 1
    return model_n, data_n, batch_shards


def analytic_memory_bytes(cfg: ModelConfig, shape_name: str, chips: int) -> float:
    """Principled minimum HBM traffic per device per step (documented in
    EXPERIMENTS.md §Roofline).  The HLO 'bytes accessed' figure is a naive
    per-op sum on the CPU backend (pre-TPU-fusion), so this analytic floor
    accompanies it; hillclimbs track both.

    train  : params (fwd read + bwd read + update write, bf16) + optimizer
             moments (read+write, f32) + remat-saved layer inputs (r+w).
    prefill: params read + KV-cache write (layout-aware compressed bytes)
             + 2x activations stream.
    decode : params read (one read per step, batch-amortized) + compressed
             KV-cache read — the paper's target term.
    """
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    model_n, data_n, batch_shards = _serve_shards(chips, B)
    # train: FSDP over (data×model); serve: TP over model only (replicated
    # across data) — matches the rule tables in distributed/sharding.py.
    n_local_train = cfg.param_count() / chips
    n_local_serve = cfg.param_count() / model_n
    d = cfg.d_model

    def kv_bytes_per_token_layer() -> float:
        """Bytes per cached token per attention layer, averaged over the
        CompressionPolicy's per-layer resolved layouts (each CacheLayout
        owns its analytic size model — no layout-name branching here)."""
        if not cfg.has_attention:
            return 0.0
        from repro.core import layouts as cache_layouts

        Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        specs = M.cache_specs(cfg, S)
        if not specs:
            return 0.0
        per_layer = [cache_layouts.get_layout(sp.layout).bytes_per_token(sp, Hkv, Dh)
                     for sp in specs]
        return sum(per_layer) / len(per_layer)

    def n_attn_layers() -> int:
        if cfg.family == "hybrid":
            return cfg.n_layers // cfg.hybrid_period
        return cfg.n_layers if cfg.has_attention else 0

    kv_pt = kv_bytes_per_token_layer()
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S

    if info["kind"] == "train":
        tokens_local = B * S / data_n  # batch sharded on data axes
        mb_tokens = tokens_local / 8  # default microbatches=8
        act = cfg.n_layers * mb_tokens * d * 2 * 2  # saved inputs r+w
        return 3 * 2 * n_local_train + 2 * 8 * n_local_train + act
    if info["kind"] == "prefill":
        tokens_local = B * S / batch_shards
        kv_w = B * ctx * kv_pt * n_attn_layers() / (batch_shards * model_n)
        act = 2 * cfg.n_layers * tokens_local * d * 2 / model_n
        return 2 * n_local_serve + kv_w + act
    # decode
    kv_r = B * ctx * kv_pt * n_attn_layers() / (batch_shards * model_n)
    ssm_state = 0.0
    if cfg.ssm_state:
        n_mamba = cfg.n_layers - (cfg.n_layers // cfg.hybrid_period
                                  if cfg.hybrid_period else 0)
        ssm_state = (2 * B * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
                     * 4 * n_mamba / (batch_shards * model_n))
    return 2 * n_local_serve + kv_r + ssm_state


def decode_kv_traffic(cfg: ModelConfig, shape_name: str, chips: int) -> dict | None:
    """Per-device decode-step KV HBM traffic, fused vs materializing.

    The fused/blockwise backends (DESIGN.md §9) stream each layer's
    COMPRESSED bytes exactly once per step — `CacheLayout.bytes_per_token`
    payload+scales, no dequantized writeback.  The retired materializing
    attend reads the same compressed bytes, then writes the dequantized
    ``[B, Hkv, NB, T, D]`` K/V intermediate to HBM and reads it back for the
    matvec: + 2x the RAW cache bytes per step.  The ratio is the
    data-movement win the paper's Fetch-stage co-design claims; the roofline
    charges the production (fused) number.
    """
    info = SHAPES[shape_name]
    if info["kind"] != "decode" or not cfg.has_attention:
        return None
    from repro.core import layouts as cache_layouts

    B, S = info["batch"], info["seq"]
    model_n, _, batch_shards = _serve_shards(chips, B)
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    specs = M.cache_specs(cfg, S)
    if not specs:
        return None
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    shard = batch_shards * model_n
    comp_pt = sum(cache_layouts.get_layout(sp.layout).bytes_per_token(sp, Hkv, Dh)
                  for sp in specs) / len(specs)
    raw_pt = 2.0 * Hkv * Dh * 2  # K+V bf16 — the dequantized intermediate
    n_layers = len(specs)
    fused = B * ctx * comp_pt * n_layers / shard
    materialized = fused + 2.0 * B * ctx * raw_pt * n_layers / shard
    return {
        "fused_bytes": fused,
        "materialized_bytes": materialized,
        "traffic_ratio": materialized / max(fused, 1.0),
        "fused_s": fused / HW["hbm_bw"],
        "materialized_s": materialized / HW["hbm_bw"],
    }


def roofline_terms(analysis: dict, chips: int,
                   analytic_bytes: float | None = None) -> dict:
    # cost_analysis numbers come from the per-device partitioned module, so
    # global = per_device * chips and the prescribed terms
    #   term = global_quantity / (chips * per_chip_rate)
    # reduce to per_device_quantity / per_chip_rate.
    comp = analysis["flops"] / HW["peak_flops"]
    mem = analysis["bytes"] / HW["hbm_bw"]
    coll = analysis["collectives"]["total"] / HW["ici_bw"]
    out = {"compute_s": comp, "memory_s": mem, "collective_s": coll}
    if analytic_bytes is not None:
        out["memory_analytic_s"] = analytic_bytes / HW["hbm_bw"]
        dom = max(("compute", comp), ("memory", out["memory_analytic_s"]),
                  ("collective", coll), key=lambda kv: kv[1])
    else:
        dom = max(("compute", comp), ("memory", mem), ("collective", coll),
                  key=lambda kv: kv[1])
    out["bottleneck"] = dom[0]
    return out


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    info = SHAPES[shape_name]
    D = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    n = cfg.active_param_count()
    mult = 6 if info["kind"] == "train" else 2
    return float(mult * n * D)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             analysis: bool = True, force: bool = False) -> dict:
    cfg = registry.get_config(arch)
    out_path = ARTIFACTS / mesh_kind / f"{arch}__{shape_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "pending", "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    try:
        fn, args, (in_sh, out_sh) = build_cell(cfg, shape_name, mesh)
        kind = SHAPES[shape_name]["kind"]
        donate = (0,) if kind == "train" else ((3,) if kind == "decode" else None)
        compiled, times = lower_compile(
            fn, args if isinstance(args, tuple) else (args,), in_sh, out_sh,
            donate=donate)
        rec.update(times)
        rec["memory"] = memory_numbers(compiled)
        rec["cost_raw"] = cost_numbers(compiled)  # loop bodies counted once
        rec["status"] = "ok"
        if analysis and mesh_kind == "pod":
            rec["analysis"] = run_analysis(cfg, shape_name, mesh)
            rec["analytic_memory_bytes"] = analytic_memory_bytes(cfg, shape_name, chips)
            rec["roofline"] = roofline_terms(rec["analysis"], chips,
                                             rec["analytic_memory_bytes"])
            traffic = decode_kv_traffic(cfg, shape_name, chips)
            if traffic is not None:
                rec["decode_kv_traffic"] = traffic
            rec["model_flops"] = model_flops(cfg, shape_name)
            hlo_global = rec["analysis"]["flops"] * chips  # cost_analysis is per device
            rec["hlo_flops_global"] = hlo_global
            rec["useful_flops_ratio"] = (rec["model_flops"] / hlo_global) if hlo_global else None
    except Exception as e:  # noqa: BLE001 — record the failure, don't hide it
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = registry.ASSIGNED if (args.all or not args.arch) else [registry.canonical(args.arch)]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind,
                               analysis=not args.no_analysis, force=args.force)
                dt = time.time() - t0
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_fail += rec["status"] == "failed"
                extra = ""
                if rec["status"] == "ok" and "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s"
                             f" ma={r.get('memory_analytic_s', 0):.3f}s"
                             f" x={r['collective_s']:.3f}s")
                    if "decode_kv_traffic" in rec:
                        extra += (" kv_fused/mat="
                                  f"1/{rec['decode_kv_traffic']['traffic_ratio']:.1f}x")
                if rec["status"] == "failed":
                    extra = " " + rec["error"][:120]
                print(f"[{mesh_kind}] {arch:22s} {shape_name:12s} "
                      f"{rec['status']:8s} ({dt:.1f}s){extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
