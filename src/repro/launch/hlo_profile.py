import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)

"""HLO collective/dot profiler — the dry-run 'profiler' for §Perf.

Parses a cell's loop-free analysis compile and prints the top collective ops
(grouped by op kind × shape) and the top dots by FLOPs, so hillclimb
hypotheses are grounded in the lowered IR rather than guesses.

    python -m repro.launch.hlo_profile --arch qwen3_moe_30b_a3b --shape prefill_32k
"""

import argparse
import collections
import dataclasses
import re

from repro.launch import dryrun
from repro.models import registry

_DEF_RE = re.compile(r"^\s*%?([\w.\-]+) = ([a-z0-9]+)\[([0-9,]*)\][^ ]* ([a-z\-]+)\(")
_DOT_DIMS = re.compile(r"dot\(|dot-general")


def profile_cell(arch: str, shape_name: str, top: int = 12):
    cfg = registry.get_config(arch)
    mesh = dryrun.make_production_mesh(multi_pod=False)
    c1, c2, d1, d2 = dryrun.analysis_depths(cfg)
    if cfg.ssm_state:
        c1 = dataclasses.replace(c1, ssm_chunk=2048)
    kind = dryrun.SHAPES[shape_name]["kind"]
    kw = dict(q_chunk=8192, kv_chunk=8192, unroll=True)
    if kind == "train":
        fn, (st, bs), (in_sh, out_sh) = dryrun.build_train_cell(
            c1, shape_name, mesh, microbatches=1, **kw)
        compiled, _ = dryrun.lower_compile(fn, (st, bs), in_sh, out_sh)
    elif kind == "prefill":
        fn, args, (in_sh, out_sh) = dryrun.build_cell(c1, shape_name, mesh, **kw)
        compiled, _ = dryrun.lower_compile(fn, args, in_sh, out_sh)
    else:
        fn, args, (in_sh, out_sh) = dryrun.build_cell(c1, shape_name, mesh, unroll=True)
        compiled, _ = dryrun.lower_compile(fn, args, in_sh, out_sh)

    hlo = compiled.as_text()
    coll = collections.Counter()
    dots = collections.Counter()
    for line in hlo.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, dt, dims, op = m.groups()
        nbytes = dryrun._shape_bytes(dt, dims)
        if op in dryrun.COLLECTIVES:
            coll[(op, f"{dt}[{dims}]")] += nbytes
        if op in ("dot", "dot-general") or "dot(" in line:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            dots[f"{dt}[{dims}]"] += n  # output elements ~ flops proxy
    print(f"== {arch} {shape_name} (depth-{d1} analysis compile) ==")
    print(f"-- top collectives by bytes (per device, one unit depth) --")
    for (op, shp), b in coll.most_common(top):
        print(f"  {b / 1e9:8.3f} GB  {op:20s} {shp[:80]}")
    print(f"-- top dot outputs by elements --")
    for shp, n in dots.most_common(top // 2):
        print(f"  {n / 1e9:8.3f} Gelem  {shp[:80]}")
    total = sum(coll.values())
    print(f"total collective bytes: {total / 1e9:.2f} GB/device at depth {d1}")
    return coll


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    profile_cell(args.arch, args.shape, args.top)
