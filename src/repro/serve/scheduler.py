"""Continuous-batching scheduler: the Server / Session serving surface.

The paper positions KVComp as the cache layer for "both latency-critical and
throughput-critical inference systems" (§5); this module supplies the
throughput side.  Instead of the old lockstep bucket batcher (every row of a
group shared one scalar position, finished rows burned masked decode steps,
and nobody could join until the whole group drained), the server owns a ring
of **slots** over one live decode state and runs an admission queue:

    submit -> queue -> [admit: solo prefill -> splice into a free slot]
           -> decode steps (every slot at its own position)
           -> retire at EOS / length -> slot reused by the next request

Per-slot state is three per-row vectors (current token, position, and the
cache's own per-row ``n_flushed``/``buf_len``), so requests with different
prompt lengths and budgets decode side by side with no padding waste — the
per-row position contract threaded through ``models.model.decode_step``,
``models.attention.attn_block_decode``, and ``core.cache`` (DESIGN.md §8).
Decode attention dispatches through the backend registry (DESIGN.md §9): on
TPU the server runs the fused in-situ-decompression kernel by default, and
the per-row vectors flow into its scalar-prefetch args unchanged;
``ServerConfig.attn_backend`` pins a specific backend.

The server is cooperative: there is no background thread.  ``Handle.result``
and ``Handle.tokens`` pump ``Server.step`` until their request completes, and
``Server.run`` drains everything; each step is one admission sweep plus one
batched decode step.  Prefill runs per admission at the request's exact
prompt length (bit-identical to a solo run — no bucket padding enters the
cache); jit caches one compiled prefill per distinct prompt length.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [S]
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Result:
    tokens: np.ndarray   # int32 [n], n <= max_new_tokens — truncated at eos_id
    prompt_len: int
    gen_s: float         # this request's wall time from prefill end to last token
    prefill_s: float     # this request's own prefill wall time
    finish_reason: str = "length"  # "eos" | "length"


@dataclasses.dataclass
class ServerConfig:
    max_slots: int = 8   # concurrent decode rows (the batch of the live state)
    max_seq: int = 4096
    greedy: bool = True
    pad_id: int = 0      # fed to inactive rows (their outputs are ignored)
    # Admission policy: "fcfs" (arrival order — predictable streaming
    # latency) or "ljf" (longest remaining budget first — packs slot loads
    # evenly, shrinking the drain tail; the throughput-bench setting).
    policy: str = "fcfs"
    # Decode-attention backend override (repro.kernels.ops registry); None
    # keeps the model config's own attn_backend (default "auto": the fused
    # in-situ-decompression kernel on TPU, blockwise-XLA scan elsewhere).
    attn_backend: str | None = None


class Handle:
    """One submitted request's session: streaming tokens and the final result.

    The handle is also the driver — ``result()`` and ``tokens()`` call
    ``Server.step`` until this request retires, so a caller that only cares
    about one request still advances everyone else's decode.
    """

    def __init__(self, server: "Server", request: Request):
        self._server = server
        self.request = request
        self._toks: list[int] = []
        self._finish: str | None = None
        self._prefill_s = 0.0
        self._t_start: float | None = None
        self._t_end: float | None = None

    @property
    def done(self) -> bool:
        return self._finish is not None

    def tokens(self) -> Iterator[int]:
        """Stream generated token ids as they are produced (drives the
        server's step loop while waiting for the next one)."""
        i = 0
        while True:
            while i < len(self._toks):
                yield self._toks[i]
                i += 1
            if self.done:
                return
            self._server.step()

    def result(self) -> Result:
        """Block (drive the server) until this request finishes."""
        while not self.done:
            self._server.step()
        return Result(
            tokens=np.asarray(self._toks, np.int32),
            prompt_len=len(self.request.prompt),
            gen_s=self._t_end - self._t_start,
            prefill_s=self._prefill_s,
            finish_reason=self._finish,
        )

    # -- scheduler side -------------------------------------------------------
    def _push(self, tok: int) -> bool:
        """Record one generated token; returns True when the request is done
        (EOS seen or budget exhausted).  Tokens after EOS are never recorded
        — results are truncated at eos_id by construction."""
        self._toks.append(int(tok))
        r = self.request
        if r.eos_id is not None and int(tok) == r.eos_id:
            self._finish = "eos"
        elif len(self._toks) >= r.max_new_tokens:
            self._finish = "length"
        else:
            return False
        self._t_end = time.monotonic()
        return True


class Server:
    """Slot-based continuous-batching server over the compressed KV cache."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig | None = None,
                 q_chunk: int = 512, kv_chunk: int = 512):
        scfg = scfg if scfg is not None else ServerConfig()
        if not scfg.greedy:
            raise NotImplementedError("only greedy decoding is served for now")
        if scfg.policy not in ("fcfs", "ljf"):
            raise ValueError(f"unknown admission policy {scfg.policy!r}")
        if scfg.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {scfg.max_slots}")
        if scfg.attn_backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=scfg.attn_backend)
        self.cfg, self.params, self.scfg = cfg, params, scfg
        B = scfg.max_slots
        self._slots: list[Handle | None] = [None] * B
        self._queue: collections.deque[Handle] = collections.deque()
        self._cur = np.full(B, scfg.pad_id, np.int32)   # last token per slot
        self._pos = np.zeros(B, np.int32)               # per-row decode position
        self.state = M.init_decode_state(cfg, B, scfg.max_seq)

        # Greedy argmax runs inside the jitted closures so each step/admit is
        # one dispatch transferring [B] token ids, not [B, V] logits.
        def _prefill(p, t):
            logits, st = M.prefill(p, cfg, {"tokens": t}, scfg.max_seq,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        def _decode(p, t, pos, st):
            logits, st = M.decode_step(p, cfg, t, pos, st)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), st

        self._prefill = jax.jit(_prefill)
        # The previous state dies on reassignment every step/admission, so
        # its buffers are donated instead of copied.
        self._decode = jax.jit(_decode, donate_argnums=(3,))
        self._insert = jax.jit(M.insert_decode_row, donate_argnums=(0,))

    # -- intake ---------------------------------------------------------------
    def submit(self, request: Request) -> Handle:
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(request.prompt) + request.max_new_tokens > self.scfg.max_seq:
            raise ValueError(
                f"prompt ({len(request.prompt)}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_seq {self.scfg.max_seq}")
        h = Handle(self, request)
        self._queue.append(h)
        return h

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- slot lifecycle -------------------------------------------------------
    def _admit(self, handle: Handle, row: int) -> bool:
        """Prefill a queued request at its exact prompt length and splice it
        into slot ``row`` of the live decode state.  Returns False when the
        request finished at prefill (budget of 1, or instant EOS) and the
        slot stays free."""
        req = handle.request
        prompt = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        t0 = time.monotonic()
        first_tok, solo = self._prefill(self.params, prompt)
        first = int(first_tok[0])
        t1 = time.monotonic()
        handle._prefill_s = t1 - t0
        handle._t_start = t1
        if handle._push(first):
            return False
        self.state = self._insert(self.state, solo, row)
        self._slots[row] = handle
        self._cur[row] = first
        self._pos[row] = len(req.prompt)
        return True

    def _pop_next(self) -> Handle:
        if self.scfg.policy == "ljf":
            pick = max(range(len(self._queue)),
                       key=lambda i: self._queue[i].request.max_new_tokens)
            self._queue.rotate(-pick)
            h = self._queue.popleft()
            self._queue.rotate(pick)
            return h
        return self._queue.popleft()

    def step(self) -> bool:
        """Admit whatever fits, then run one batched decode step over the
        live slots.  Returns True while work remains (active or queued)."""
        free = [i for i, s in enumerate(self._slots) if s is None]
        while free and self._queue:
            if self._admit(self._pop_next(), free[0]):
                free.pop(0)
        rows = [i for i, s in enumerate(self._slots) if s is not None]
        if not rows:
            return bool(self._queue)
        toks, self.state = self._decode(
            self.params, jnp.asarray(self._cur), jnp.asarray(self._pos),
            self.state)
        nxt = np.asarray(toks)
        for row in rows:
            tok = int(nxt[row])
            self._cur[row] = tok
            self._pos[row] += 1
            if self._slots[row]._push(tok):
                self._slots[row] = None  # retire; slot reused next step
        return bool(self._queue) or any(s is not None for s in self._slots)

    def run(self) -> None:
        """Drain: step until every submitted request has finished."""
        while self.step():
            pass

    def memory_report(self) -> dict:
        """Measured bytes of the live decode state (all slots)."""
        return cache_memory_report(self.cfg, self.state)


def cache_memory_report(cfg: ModelConfig, state) -> dict:
    """Measured bytes of a decode state per layout — the serving-side
    memory-reduction claim, computed from the actual arrays.

    Under a per-layer ``CompressionPolicy`` the KV entry also lists each
    layer's resolved layout (the caches live in a tuple, one spec each).
    """
    tot = 0
    kv = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        nbytes = leaf.size * leaf.dtype.itemsize
        tot += nbytes
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "kv" in keys:
            kv += nbytes
    rep = {"total_bytes": int(tot), "kv_bytes": int(kv),
           "layout": cfg.cache_layout}
    caches = state.get("kv") if isinstance(state, dict) else None
    if isinstance(caches, (tuple, list)):
        rep["per_layer_layouts"] = [c.spec.layout for c in caches]
    return rep
