"""Continuous-batching scheduler: the Server / Session serving surface.

The paper positions KVComp as the cache layer for "both latency-critical and
throughput-critical inference systems" (§5); this module supplies the
throughput side.  Instead of the old lockstep bucket batcher (every row of a
group shared one scalar position, finished rows burned masked decode steps,
and nobody could join until the whole group drained), the server owns a ring
of **slots** over one live decode state and runs an admission queue:

    submit -> queue -> [admit: chunked prefill interleaved with decode]
           -> decode steps (every slot at its own position)
           -> retire at EOS / length -> slot reused by the next request

Admission is **chunked by default** (DESIGN.md §13): a queued prompt claims
a free slot as a PREFILLING row and is processed in ``block_size``-aligned
chunks spliced *between* decode steps — at most
``ServerConfig.prefill_chunk_tokens`` prompt tokens ride alongside the live
decode batch per step (Sarathi/SplitFuse-style), so one 32k prompt no
longer freezes every stream for its whole prefill.  On the paged pool the
chunk loop runs through a batch-1 *view* of the live arena
(``model.chunk_state_view``): each chunk's blocks quantize/pack straight
into pooled pages (the Store-stage ``pack_encode`` path), the prompt's KV
never materializes uncompressed at full length, and peak admission memory
drops from O(prompt) to O(chunk) — memory-pressure admission can start a
long prompt before the pool could hold its dense form.  Per-block chunk
state is a pure function of (params, earlier pages, block tokens), so
greedy outputs stay bit-identical to ``prefill_mode="solo"`` — the
blocking legacy admission kept as the explicit baseline (and the automatic
fallback for families without a chunk step).

Per-slot state is three per-row vectors (current token, position, and the
cache's own per-row ``n_flushed``/``buf_len``), so requests with different
prompt lengths and budgets decode side by side with no padding waste — the
per-row position contract threaded through ``models.model.decode_step``,
``models.attention.attn_block_decode``, and ``core.cache`` (DESIGN.md §8).
Decode attention dispatches through the backend registry (DESIGN.md §9): on
TPU the server runs the fused in-situ-decompression kernel by default, and
the per-row vectors flow into its scalar-prefetch args unchanged;
``ServerConfig.attn_backend`` pins a specific backend.

The server is cooperative: there is no background thread.  ``Handle.result``
and ``Handle.tokens`` pump ``Server.step`` until their request completes, and
``Server.run`` drains everything; each step is one admission sweep plus one
batched decode step.  Prefill runs per admission at the request's exact
prompt length (bit-identical to a solo run — no bucket padding enters the
cache); jit caches one compiled prefill per distinct prompt length.

Under ``cache_mode="paged"`` (DESIGN.md §10) the slots stop reserving a full
block ring each: compressed blocks live in one shared arena per layer
(``repro.core.pool``), admission is a memory-pressure check against the
pool's byte budget (so ``max_slots`` oversubscribes the dense-reservation
bound by the compression ratio), a page-fault sweep assigns each row its
next physical page just before the flush that needs it, and on pool
exhaustion the youngest request is preempted — pages freed, prompt replayed
on re-admission — leaving greedy tokens bit-identical to solo runs.

Observability (DESIGN.md §14): every counter the server keeps lives in a
``repro.obs.MetricsRegistry`` (``Server.metrics``) and ``stats()`` is a
view over it with ONE schema — sharded and unsharded servers emit the same
tree.  ``ServerConfig.trace`` turns on a ring-buffered structured event
log (``Server.trace``) of every scheduler decision, stamped with the same
monotonic floats ``Result`` timing is built from and exportable as a
Perfetto-loadable Chrome trace (``Server.shutdown``).

``ServerConfig.prefix_cache`` (DESIGN.md §11) layers prefix sharing on top:
admission switches to a block-chunked prefill whose per-block computation
depends only on (params, earlier blocks' pages, block tokens), a radix
index maps shared block-aligned prompt prefixes to live refcounted arena
pages, hits splice cached page ids into the new row's page table and
prefill starts at the first divergent block, a row that wraps its ring onto
a shared page copy-on-writes just that page, and preempted rows park their
blocks in the index and resume from cached pages instead of replaying.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import time
from pathlib import Path
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pool as blockpool
from repro.obs import EventTrace, MetricsRegistry
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [S]
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Result:
    tokens: np.ndarray   # int32 [n], n <= max_new_tokens — truncated at eos_id
    prompt_len: int
    gen_s: float         # this request's wall time from prefill end to last token
    prefill_s: float     # this request's own prefill wall time
    finish_reason: str = "length"  # "eos" | "length"
    # Latency decomposition (benchmarks/serve_throughput.py): time queued
    # before any prefill work started, submit-to-first-token, and the
    # monotonic emission time of every token (first production — replays
    # after a preemption keep the original stamps), for inter-token p50/p99.
    queue_wait_s: float = 0.0
    ttft_s: float = 0.0
    token_times: tuple = ()


@dataclasses.dataclass
class ServerConfig:
    max_slots: int = 8   # concurrent decode rows (the batch of the live state)
    max_seq: int = 4096
    greedy: bool = True
    pad_id: int = 0      # fed to inactive rows (their outputs are ignored)
    # Admission policy: "fcfs" (arrival order — predictable streaming
    # latency) or "ljf" (longest remaining budget first — packs slot loads
    # evenly, shrinking the drain tail; the throughput-bench setting).
    policy: str = "fcfs"
    # Decode-attention backend override (repro.kernels.ops registry); None
    # keeps the model config's own attn_backend (default "auto": the fused
    # in-situ-decompression kernel on TPU, blockwise-XLA scan elsewhere).
    attn_backend: str | None = None
    # Cache storage container override (DESIGN.md §10); None keeps the model
    # config's own cache_mode.  "paged" pools compressed blocks in a shared
    # per-layer arena sized by ``pool_hbm_bytes`` and admits by memory
    # pressure: slots oversubscribe the dense-reservation bound by the
    # compression ratio, and the youngest request is preempted + requeued
    # (prompt replayed on re-admit, greedy tokens unchanged) if the pool
    # runs dry mid-decode.
    cache_mode: str | None = None
    # Paged mode: byte budget for all layers' arenas (post-compression block
    # bytes, the unit repro.core.pool accounts in).  None defaults to the
    # dense-equivalent footprint (max_slots full ring reservations) — paged
    # then behaves as pure oversubscription with no added memory.
    pool_hbm_bytes: int | None = None
    # Prefix cache over compressed pages (DESIGN.md §11; paged mode only).
    #   "off"     — classic admission: solo full prefill from token 0.
    #   "on"      — block-chunked admission with a radix prefix index:
    #               shared prompt prefixes splice cached page ids into the
    #               new row's page table and prefill starts at the first
    #               divergent block; preempted rows park their blocks in
    #               the index and resume from cached pages.
    #   "noshare" — the accounting baseline: the identical block-chunked
    #               admission path with lookup/insert disabled, so its
    #               greedy outputs are bit-identical to "on" by
    #               construction (benchmarks/prefix_reuse.py compares the
    #               two for the prefill-FLOPs-saved gate).
    prefix_cache: str = "off"
    # Multi-device serving (DESIGN.md §12): a jax Mesh with ("data",
    # "model") axes — build it with repro.launch.mesh.make_serve_mesh.
    # "data" shards decode slots / page tables / the paged arena's page
    # axis (each data shard runs its own page pool over its slice);
    # "model" shards KV heads inside attention.  Parameters stay
    # replicated, so greedy outputs are bit-identical to the unsharded
    # server.  None (or a 1-device mesh) serves single-device.
    mesh: object | None = None
    # Admission prefill (DESIGN.md §13):
    #   "chunked" — default: prompts prefill in block-aligned chunks spliced
    #               between decode steps, at most ``prefill_chunk_tokens``
    #               prompt tokens per server step across all PREFILLING
    #               rows.  Greedy outputs are bit-identical to "solo".
    #               Families without a chunk step (ssm/hybrid) and
    #               non-uniform block sizes fall back to "solo".
    #   "solo"    — legacy blocking admission: the whole prompt prefills in
    #               one call while every live decode stream waits (the p99
    #               baseline benchmarks/serve_throughput.py compares against).
    prefill_mode: str = "chunked"
    # Per-step chunked-prefill token budget; must be a positive multiple of
    # the cache block_size (checked against the model's spec at Server
    # construction, mirroring CacheSpec's window check).  None = 8 blocks.
    prefill_chunk_tokens: int | None = None
    # Structured event trace (DESIGN.md §14):
    #   "off"    — no events recorded; the trace call sites reduce to one
    #              host branch per decision (zero events, zero added device
    #              dispatches — greedy outputs bit-identical by construction).
    #   "events" — every scheduler decision: admit, prefill chunk splice,
    #              page-fault sweep outcome, CoW break, prefix hit/evict,
    #              preempt/requeue, retire, token emission.
    #   "full"   — "events" plus the per-step decode-dispatch firehose.
    trace: str = "off"
    # Ring capacity of the event trace; a longer run keeps the most recent
    # window and reports how many events it dropped.
    trace_capacity: int = 65536

    def __post_init__(self):
        if self.prefill_mode not in ("chunked", "solo"):
            raise ValueError(
                f"prefill_mode must be chunked|solo, got {self.prefill_mode!r}")
        if (self.prefill_chunk_tokens is not None
                and self.prefill_chunk_tokens < 1):
            raise ValueError(
                "prefill_chunk_tokens must be a positive multiple of the "
                f"cache block_size, got {self.prefill_chunk_tokens}")
        if self.trace not in ("off", "events", "full"):
            raise ValueError(
                f"trace must be off|events|full, got {self.trace!r}")


class Handle:
    """One submitted request's session: streaming tokens and the final result.

    The handle is also the driver — ``result()`` and ``tokens()`` call
    ``Server.step`` until this request retires, so a caller that only cares
    about one request still advances everyone else's decode.
    """

    def __init__(self, server: "Server", request: Request):
        self._server = server
        self.request = request
        self.id = -1  # request id, assigned by Server.submit (trace track)
        self._toks: list[int] = []
        self._finish: str | None = None
        self._prefill_s = 0.0
        self._t_submit = time.monotonic()
        self._t_first: float | None = None  # first prefill work (queue wait end)
        self._t_start: float | None = None
        self._t_end: float | None = None
        self._token_times: list[float] = []

    @property
    def done(self) -> bool:
        return self._finish is not None

    def tokens(self) -> Iterator[int]:
        """Stream generated token ids as they are produced (drives the
        server's step loop while waiting for the next one)."""
        i = 0
        while True:
            while i < len(self._toks):
                yield self._toks[i]
                i += 1
            if self.done:
                return
            self._server.step()

    def result(self) -> Result:
        """Block (drive the server) until this request finishes."""
        while not self.done:
            self._server.step()
        t_first = self._t_first if self._t_first is not None else self._t_submit
        return Result(
            tokens=np.asarray(self._toks, np.int32),
            prompt_len=len(self.request.prompt),
            gen_s=self._t_end - self._t_start,
            prefill_s=self._prefill_s,
            finish_reason=self._finish,
            queue_wait_s=t_first - self._t_submit,
            ttft_s=(self._token_times[0] - self._t_submit
                    if self._token_times else 0.0),
            token_times=tuple(self._token_times),
        )

    # -- scheduler side -------------------------------------------------------
    def _push(self, tok: int) -> bool:
        """Record one generated token; returns True when the request is done
        (EOS seen or budget exhausted).  Tokens after EOS are never recorded
        — results are truncated at eos_id by construction."""
        srv = self._server
        self._toks.append(int(tok))
        # Emission time of each NEW token index: after a (non-prefix)
        # preemption clears + replays the list, earlier indices keep the
        # stamp of their first production — the stream a caller saw.
        # Fresh stamps feed the latency histograms and (when tracing) emit
        # ``token`` events carrying the SAME float, so trace-reconstructed
        # TTFT/ITL equal the Result fields exactly; replays observe nothing.
        if len(self._toks) > len(self._token_times):
            t = time.monotonic()
            self._token_times.append(t)
            if len(self._token_times) == 1:
                srv._h_ttft.observe(t - self._t_submit)
            else:
                srv._h_itl.observe(t - self._token_times[-2])
            if srv._tr is not None:
                srv._tr.emit("token", req=self.id, t=t,
                             index=len(self._token_times) - 1)
        r = self.request
        if r.eos_id is not None and int(tok) == r.eos_id:
            self._finish = "eos"
        elif len(self._toks) >= r.max_new_tokens:
            self._finish = "length"
        else:
            return False
        self._t_end = time.monotonic()
        if srv._tr is not None:
            srv._tr.emit("retire", req=self.id, t=self._t_end,
                         reason=self._finish)
        return True


@dataclasses.dataclass
class _PrefillTask:
    """One PREFILLING row's host-side progress (DESIGN.md §13).

    ``pos`` is always block-aligned between server steps (partial tail
    chunks only run as the finishing chunk).  ``state is None`` marks the
    fused arena path: flushed blocks already live in this row's pooled
    pages (``Server._pt_host[row]``) while the DEVICE page-table row stays
    cleared — the concurrently decoding batch write-drops and read-masks
    the row until the finish chunk installs it.  Otherwise ``state`` is a
    private batch-1 dense chunk state (dense cache mode, or paged under a
    mesh where the replicated solo state keeps sharded parity) spliced in
    at the finish."""

    handle: Handle
    row: int
    forced: np.ndarray      # prompt + pre-preemption generations, i32 [n]
    n: int
    pos: int                # tokens chunked so far
    hit: list               # prefix-cache pages spliced below ``pos``
    state: object | None    # None => fused encode-to-page path
    chunks: int = 0


class Server:
    """Slot-based continuous-batching server over the compressed KV cache."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig | None = None,
                 q_chunk: int = 512, kv_chunk: int = 512):
        scfg = scfg if scfg is not None else ServerConfig()
        if not scfg.greedy:
            raise NotImplementedError("only greedy decoding is served for now")
        if scfg.policy not in ("fcfs", "ljf"):
            raise ValueError(f"unknown admission policy {scfg.policy!r}")
        if scfg.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {scfg.max_slots}")
        if scfg.attn_backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=scfg.attn_backend)
        if scfg.cache_mode is not None:
            cfg = dataclasses.replace(cfg, cache_mode=scfg.cache_mode)
        if cfg.cache_mode not in ("dense", "paged"):
            raise ValueError(f"unknown cache_mode {cfg.cache_mode!r}")
        self.paged = cfg.cache_mode == "paged"
        if self.paged and M.n_cache_layers(cfg) == 0:
            raise ValueError(
                "paged cache mode needs attention KV caches "
                f"(family {cfg.family!r} has none)")
        self.cfg, self.params, self.scfg = cfg, params, scfg
        B = scfg.max_slots
        self._slots: list[Handle | None] = [None] * B
        self._queue: collections.deque[Handle] = collections.deque()
        self._cur = np.full(B, scfg.pad_id, np.int32)   # last token per slot
        self._pos = np.zeros(B, np.int32)               # per-row decode position
        self._seq = 0                                   # admission counter
        self._row_seq = [0] * B                         # admission order per row
        self._next_req_id = 0
        # Observability (DESIGN.md §14): one registry carries every counter
        # this server and its components (pool, prefix indexes) keep;
        # ``stats()`` is a view over it.  The event trace records scheduler
        # decisions when enabled; ``self._tr`` is the hot-path gate — None
        # when tracing is off, so every call site is a single host branch.
        self.metrics = MetricsRegistry()
        self.trace = EventTrace(scfg.trace, scfg.trace_capacity)
        self._tr = self.trace if self.trace.enabled else None
        self._preemptions = self.metrics.counter("serve.preemptions")
        self._h_ttft = self.metrics.histogram("serve.ttft_s")
        self._h_itl = self.metrics.histogram("serve.itl_s")
        self._h_queue = self.metrics.histogram("serve.queue_wait_s")
        self._g_active = self.metrics.gauge("serve.active")
        self._g_pending = self.metrics.gauge("serve.pending")
        # Chunked admission (DESIGN.md §13): PREFILLING rows by slot index.
        # A slot is busy while it appears in EITHER _slots or _prefill_tasks.
        self._prefill_tasks: dict[int, _PrefillTask] = {}
        self._pf = {k: self.metrics.counter(f"serve.prefill.{k}")
                    for k in ("prefill_tokens", "chunks",
                              "coscheduled_tokens", "stalled_decode_steps",
                              "prefill_preemptions")}

        # Chunk capability: the block-chunked prefill step exists only for
        # pure-KV families, and block-aligned chunks need one block_size
        # across layers (per-layer n_blocks/windows may still differ in
        # dense mode).  Capable families take the UNIFIED chunk-loop
        # admission in both prefill modes — "solo" drains every chunk at
        # admission (the stall), "chunked" interleaves them with decode —
        # so the two modes are bit-identical by construction.  Anything
        # else falls back to the legacy full-length-prefill admission.
        specs = (M.cache_specs(cfg, scfg.max_seq)
                 if M.n_cache_layers(cfg) else ())
        uniform_t = len({s.block_size for s in specs}) == 1
        self._spec0 = specs[0] if uniform_t else None
        self.prefill_unified = cfg.family in ("dense", "moe") and uniform_t
        self.prefill_chunked = (scfg.prefill_mode == "chunked"
                                and self.prefill_unified)
        self._chunk_budget = self._chunk_t = None
        if self.prefill_unified:
            T = self._spec0.block_size
            budget = scfg.prefill_chunk_tokens
            if budget is None:
                budget = 8 * T
            elif budget % T:
                raise ValueError(
                    f"prefill_chunk_tokens ({budget}) must be a positive "
                    f"multiple of block_size ({T}): chunked admission "
                    "flushes whole compression blocks between decode steps")
            self._chunk_budget, self._chunk_t = int(budget), T

        # Multi-device serving (DESIGN.md §12): normalize a trivial mesh to
        # None so single-device runs trace the exact unsharded graphs, then
        # pin the LIVE decode state's spec to the "sharded" attention
        # backend — a shard_map over (data, model) around the inner backend.
        mesh = scfg.mesh
        self._n_data = self._n_model = 1
        if mesh is not None:
            from repro.distributed import serve_shard
            n_d, n_m = serve_shard.mesh_counts(mesh)
            if n_d * n_m <= 1:
                mesh = None
            else:
                self._n_data, self._n_model = serve_shard.validate_serve_mesh(
                    mesh, cfg, B)
        self.mesh = mesh
        # The backend the shard_map wraps per shard (what an unsharded
        # server would have dispatched); resolved at trace time so the
        # REPRO_ATTN_BACKEND matrix steers both paths identically.
        self._inner_backend = cfg.attn_backend
        cfg_live = (dataclasses.replace(cfg, attn_backend="sharded")
                    if mesh is not None else cfg)
        self._slots_per_shard = B // self._n_data
        self._preempt_by_shard = [
            self.metrics.counter(f"serve.shard{d}.preemptions")
            for d in range(self._n_data)]
        if mesh is not None:
            serve_shard.set_serve_mesh(mesh, self._inner_backend)

        if self.paged:
            # Size the shared arenas from the byte budget: one page = one
            # compression block across all layers (uniform block_size means
            # every layer flushes the same logical block at the same step,
            # so one page id serves all arenas), accounted in actual
            # post-compression bytes per layer (repro.core.pool.page_nbytes).
            if len({(s.block_size, s.n_blocks) for s in specs}) > 1:
                raise ValueError(
                    "paged mode requires a uniform block_size across layers")
            self._spec0 = specs[0]
            per_layer = tuple(
                blockpool.page_nbytes(s, cfg.n_kv_heads, cfg.resolved_head_dim)
                for s in specs)
            nb = self._spec0.n_blocks
            budget = scfg.pool_hbm_bytes
            if budget is None:
                budget = B * nb * sum(per_layer)  # dense-equivalent footprint
            n_pages = int(budget // max(sum(per_layer), 1))
            # Sharded arena: the page axis splits evenly over data shards
            # (each shard's pool owns a contiguous id slice), so round the
            # count down to a multiple of the shard count.
            n_pages -= n_pages % self._n_data
            if n_pages < 1:
                raise ValueError(
                    f"pool_hbm_bytes={budget} holds no page per data shard "
                    f"(one page across layers is {sum(per_layer)} bytes, "
                    f"{self._n_data} shard(s))")
            if self._n_data > 1:
                self.pool = serve_shard.ShardedPagedPool(
                    n_pages, per_layer, self._n_data)
                shard_pools = self.pool.shards
            else:
                self.pool = blockpool.PagedBlockPool(n_pages, per_layer)
                shard_pools = [self.pool]
            # Adopt the pools' own metric objects into this server's
            # registry — one tree regardless of sharding (shard 0 IS the
            # whole pool unsharded).
            for d, p in enumerate(shard_pools):
                self.metrics.register(
                    f"pool.shard{d}.high_water_pages", p.m_high_water)
                self.metrics.register(
                    f"pool.shard{d}.alloc_pages", p.m_alloc_pages)
                self.metrics.register(
                    f"pool.shard{d}.freed_pages", p.m_freed_pages)
            # Host mirror of the device page tables (one logical table
            # shared by every layer): rows index slots, entries are pages.
            self._pt_host = np.full((B, nb), -1, np.int64)
            self.state = M.init_decode_state(cfg_live, B, scfg.max_seq,
                                             pool_pages=n_pages)
        else:
            self.pool = None
            self.state = M.init_decode_state(cfg_live, B, scfg.max_seq)

        # Place the live state against its canonical shardings up front and
        # re-constrain every state-producing closure's output to them below:
        # stable placement across steps (no resharding thrash), and donation
        # stays buffer-compatible.
        if mesh is not None:
            self._shardings = serve_shard.decode_state_shardings(self.state, mesh)
            self.state = jax.device_put(self.state, self._shardings)
        else:
            self._shardings = None

        if scfg.prefix_cache not in ("off", "on", "noshare"):
            raise ValueError(
                f"prefix_cache must be off|on|noshare, got {scfg.prefix_cache!r}")
        self.prefix_mode = scfg.prefix_cache != "off"
        self._share = scfg.prefix_cache == "on"
        self.index = None
        if self.prefix_mode:
            if not self.paged:
                raise ValueError(
                    "prefix_cache shares pages of the pooled arena; it needs "
                    "cache_mode='paged'")
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    "prefix_cache needs a pure-KV decode state (block-chunked "
                    f"prefill has no {cfg.family!r} step)")
            if self._share:
                from repro.serve.prefix import PrefixIndex
                # One index per data shard: a prefix is only reusable by
                # rows whose pages live on the same shard (a page table can
                # only point at its own shard's arena slice).
                self._indexes = [PrefixIndex(self._spec0.block_size)
                                 for _ in range(self._n_data)]
                self.index = self._indexes[0]
                for d, ix in enumerate(self._indexes):
                    self.metrics.register(
                        f"prefix.index.shard{d}.inserted_blocks",
                        ix.m_inserted_blocks)
                    self.metrics.register(
                        f"prefix.index.shard{d}.evicted_blocks",
                        ix.m_evicted_blocks)
            self._pfx = {k: self.metrics.counter(f"prefix.{k}")
                         for k in ("lookups", "hits", "hit_blocks",
                                   "reused_tokens", "prefill_tokens",
                                   "prefill_attn_pairs", "resumes",
                                   "resume_reused_blocks", "cow_breaks")}

        # Greedy argmax runs inside the jitted closures so each step/admit is
        # one dispatch transferring [B] token ids, not [B, V] logits.
        # Prefill always builds the DENSE twin of the cache spec (admission
        # prefills are solo: a private full ring at the exact prompt length,
        # bit-identical to a solo run); the paged splice scatters its blocks
        # into the arena pages afterwards.
        def _prefill(p, t):
            logits, st = M.prefill(p, cfg, {"tokens": t}, scfg.max_seq,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        # Sharded serving: every closure that produces the NEXT live state
        # pins its output to the canonical shardings (``_c``) so placement
        # never drifts between steps; without a mesh the closures are the
        # exact unsharded traces.
        shardings = self._shardings
        if mesh is not None:
            def _c(st):
                return serve_shard.constrain_state(st, shardings)
        else:
            def _c(st):
                return st

        def _decode(p, t, pos, st):
            logits, st = M.decode_step(p, cfg, t, pos, st)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), _c(st)

        self._prefill = jax.jit(_prefill)
        # The previous state dies on reassignment every step/admission, so
        # its buffers are donated instead of copied.
        self._decode = jax.jit(_decode, donate_argnums=(3,))
        if self.paged:
            self._insert = jax.jit(
                lambda dst, src, row, pages:
                    _c(M.insert_decode_row_paged(dst, src, row, pages)),
                donate_argnums=(0,))
            self._assign = jax.jit(
                lambda st, r, s, p: _c(M.assign_cache_pages(st, r, s, p)),
                donate_argnums=(0,))
            self._clear = jax.jit(
                lambda st, r: _c(M.clear_cache_row(st, r)),
                donate_argnums=(0,))
        else:
            # Dense insert tree_maps dst against the solo prefill state, so
            # their static specs must agree: rewrite the solo src to the live
            # spec's backend pin first (pure aux-data relabeling — under a
            # mesh dst is pinned to "sharded" while prefill built src under
            # the plain cfg).
            def _insert_dense(dst, src, row):
                if mesh is not None:
                    src = serve_shard.override_backend(src, "sharded")
                return _c(M.insert_decode_row(dst, src, row))

            self._insert = jax.jit(_insert_dense, donate_argnums=(0,))
        if self.prefix_mode or self.prefill_unified:
            # Block-chunked prefill (DESIGN.md §11/§13): the solo state
            # chains through the chunk loop, so each step donates its
            # predecessor.  The gather reads the LIVE state (no donation),
            # the fresh-state builder re-executes per call (each admission
            # needs buffers it can donate away).
            def _chunk(p, t, pos, st):
                logits, st = M.prefill_chunk(p, cfg, t, pos, st)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), st

            def _chunk_scan(p, toks, pos0, st):
                # toks i32 [k, 1, T]: k full block_size chunks in ONE
                # dispatch — a lax.scan of the exact per-chunk computation,
                # so the result is bit-identical to k separate _chunk calls
                # while the dispatch overhead is paid once.  Compiled per
                # power-of-two k (_advance_task buckets the trip count).
                T = toks.shape[2]
                offs = pos0 + T * jnp.arange(toks.shape[0], dtype=jnp.int32)

                def step(st, xs):
                    t, pos = xs
                    logits, st = M.prefill_chunk(p, cfg, t, pos, st)
                    return st, jnp.argmax(logits, axis=-1).astype(jnp.int32)

                st, toks_out = jax.lax.scan(step, st, (toks, offs))
                return toks_out[-1], st

            self._chunk = jax.jit(_chunk, donate_argnums=(3,))
            self._chunk_scan = jax.jit(_chunk_scan, donate_argnums=(3,))
            self._fresh = jax.jit(
                lambda: M.init_decode_state(cfg, 1, scfg.max_seq))
        if self.prefill_unified and self.paged and mesh is None:
            # Fused encode-to-page chunking (DESIGN.md §13): the chunk loop
            # runs through a batch-1 VIEW sharing the live arena, so each
            # chunk's blocks compress straight into this row's pooled pages
            # while the view's page-table row keeps the batch write-dropped.
            # The live state threads through (donated — the arena buffers
            # alias), and the finishing chunk installs the row in the same
            # trace, because a sub-block tail lives only in view buffers.
            # Under a mesh the dense-state path above is used instead: the
            # arena is GSPMD-sharded, and chunk reductions over its page
            # axis would not stay bit-stable across shardings.
            def _chunk_paged(p, t, pos0, st, pages):
                view = M.chunk_state_view(st, pages, pos0)
                tok, view = _chunk_tok(p, t, pos0, view)
                return tok, M.adopt_chunk_stores(st, view)

            def _finish_paged(p, t, pos0, st, pages, row):
                view = M.chunk_state_view(st, pages, pos0)
                tok, view = _chunk_tok(p, t, pos0, view)
                return tok, M.install_chunk_row(st, view, row, pages)

            def _chunk_tok(p, t, pos0, view):
                logits, view = M.prefill_chunk(p, cfg, t, pos0, view)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), view

            def _chunk_paged_scan(p, toks, pos0, st, pages):
                # k full chunks encode-to-page in ONE dispatch: each scan
                # step rebuilds the batch-1 view over the threaded live
                # state and adopts its arena stores, exactly the sequential
                # _chunk_paged loop.  The finishing chunk never rides in a
                # scan (install_chunk_row needs the final view's buffers),
                # so _advance_task caps the trip count short of the end.
                T = toks.shape[2]
                offs = pos0 + T * jnp.arange(toks.shape[0], dtype=jnp.int32)

                def step(st, xs):
                    t, pos = xs
                    view = M.chunk_state_view(st, pages, pos)
                    tok, view = _chunk_tok(p, t, pos, view)
                    return M.adopt_chunk_stores(st, view), tok

                st, toks_out = jax.lax.scan(step, st, (toks, offs))
                return toks_out[-1], st

            self._chunk_paged = jax.jit(_chunk_paged, donate_argnums=(3,))
            self._chunk_paged_scan = jax.jit(_chunk_paged_scan,
                                             donate_argnums=(3,))
            self._finish_paged = jax.jit(_finish_paged, donate_argnums=(3,))
        if self.prefix_mode:
            if mesh is not None:
                # gather_prefix_state keeps the live spec's "sharded"
                # backend pin on the batch-1 dense seed; rewrite it to the
                # inner backend so the solo chunk loop matches _fresh's
                # states (specs are static aux — same jit cache, same math).
                inner = self._inner_backend

                def _gather(st, seed, j):
                    return serve_shard.override_backend(
                        M.gather_prefix_state(st, seed, j), inner)

                self._gather = jax.jit(_gather)
            else:
                self._gather = jax.jit(M.gather_prefix_state)

    # -- intake ---------------------------------------------------------------
    def submit(self, request: Request) -> Handle:
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(request.prompt) + request.max_new_tokens > self.scfg.max_seq:
            raise ValueError(
                f"prompt ({len(request.prompt)}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_seq {self.scfg.max_seq}")
        if self.paged:
            # A request must be able to run SOLO: the most pages it can ever
            # hold (every block its prompt + budget can flush, ring-capped)
            # has to fit its shard's slice of the pool — a row only ever
            # allocates from its own data shard — or no amount of preemption
            # admits it.  Unsharded, the shard IS the whole pool.
            need = self._lifetime_pages(request)
            cap = (self.pool.n_pages if self._n_data == 1
                   else self.pool.per_shard)
            if need > cap:
                raise ValueError(
                    f"request needs up to {need} block pages but "
                    f"{'each data shard' if self._n_data > 1 else 'the pool'} "
                    f"holds {cap}; raise the pool byte budget "
                    "(pool_hbm_bytes= via api.serve / --pool-bytes on the "
                    "launch.serve CLI)")
        h = Handle(self, request)
        h.id = self._next_req_id
        self._next_req_id += 1
        if self._tr is not None:
            self._tr.emit("submit", req=h.id, t=h._t_submit,
                          prompt_len=len(request.prompt),
                          max_new_tokens=request.max_new_tokens)
        self._queue.append(h)
        return h

    def _lifetime_pages(self, request: Request) -> int:
        """Most pages a request can ever hold at once (ring-capped).  The
        final generated token retires the request before it is appended, so
        the cache peaks at prompt + max_new - 1 entries."""
        spec = self._spec0
        total = ((len(request.prompt) + request.max_new_tokens - 1)
                 // spec.block_size)
        return min(total, spec.n_blocks)

    def _prefill_pages(self, request: Request) -> int:
        """Pages the admission prefill writes (full prompt blocks, ring-capped)."""
        spec = self._spec0
        return min(len(request.prompt) // spec.block_size, spec.n_blocks)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def prefilling(self) -> int:
        """Rows mid-chunked-prefill (claimed but not yet decoding)."""
        return len(self._prefill_tasks)

    @property
    def preemptions(self) -> int:
        """Total rows evicted + requeued (registry-backed)."""
        return self._preemptions.value

    # -- shard-local page accounting (DESIGN.md §12) --------------------------
    # jax shards an axis into contiguous per-device chunks, so decode slot
    # ``row`` lives on data shard ``row // (max_slots / n_data)`` — and all
    # of a row's pages must come from that shard's pool slice, keeping every
    # page a live row references device-local.
    def _row_shard(self, row: int) -> int:
        return row // self._slots_per_shard if self._n_data > 1 else 0

    def _shard_pool(self, row: int):
        """The row's own allocator: the flat pool unsharded, else the
        offset pool of the row's data shard."""
        if self._n_data == 1:
            return self.pool
        return self.pool.shards[self._row_shard(row)]

    def _shard_free(self, shard: int) -> int:
        if self._n_data == 1:
            return self.pool.free_pages
        return self.pool.shards[shard].free_pages

    def _alloc(self, n: int, row: int) -> list[int]:
        return self._shard_pool(row).alloc(n)

    def _index_for(self, row: int):
        """The prefix index of the row's data shard (sharing mode only)."""
        return self._indexes[self._row_shard(row)]

    # -- slot lifecycle -------------------------------------------------------
    def _forced(self, handle: Handle) -> np.ndarray:
        """The tokens a (re-)admitted request's cache must come to contain:
        its prompt plus every token already generated before a preemption
        (prefix mode keeps them — resume continues instead of replaying)."""
        return np.concatenate([np.asarray(handle.request.prompt, np.int32),
                               np.asarray(handle._toks, np.int32)])

    def _admit(self, handle: Handle, row: int) -> bool:
        """LEGACY admission for families without a block-chunked prefill
        step (ssm/hybrid, or non-uniform per-layer block sizes): prefill
        the queued request at its exact prompt length in one shot and
        splice it into slot ``row`` of the live decode state.  Returns
        False when the request finished at prefill (budget of 1, or
        instant EOS) and the slot stays free.  Pure-KV families never come
        through here — both prefill modes run the unified chunk loop
        (``_start_prefill``; DESIGN.md §13), whose numerics differ from
        this path for lossy layouts (chunks attend earlier blocks through
        the compressed store, full-length prefill attends them raw)."""
        req = handle.request
        prompt = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        if any(s is not None for s in self._slots):
            # Solo admission freezes every live decode stream for the whole
            # prompt — the stall chunked admission exists to kill.
            self._pf["stalled_decode_steps"].inc()
        t0 = time.monotonic()
        if handle._t_first is None:
            handle._t_first = t0
            self._h_queue.observe(t0 - handle._t_submit)
        if self._tr is not None:
            self._tr.emit("admit", req=handle.id, t=t0, row=row)
        first_tok, solo = self._prefill(self.params, prompt)
        first = int(first_tok[0])
        t1 = time.monotonic()
        # Accumulate across preemption replays: prefill_s sums every prompt
        # (re)play and t_start keeps the FIRST admission, so Result.gen_s is
        # the request's true wall time under pool pressure, not just the
        # post-preemption tail.
        handle._prefill_s += t1 - t0
        if handle._t_start is None:
            handle._t_start = t1
        if handle._push(first):
            return False
        if self.paged:
            nb = self._spec0.n_blocks
            n_blk = self._prefill_pages(req)
            pages = np.full(nb, -1, np.int64)
            pages[:n_blk] = self._alloc(n_blk, row)  # _can_admit checked free
            self._pt_host[row] = pages
            self.state = self._insert(self.state, solo, row,
                                      jnp.asarray(pages, jnp.int32))
        else:
            self.state = self._insert(self.state, solo, row)
        self._slots[row] = handle
        self._cur[row] = first
        self._pos[row] = len(req.prompt)
        self._seq += 1
        self._row_seq[row] = self._seq
        return True

    # -- chunked admission (DESIGN.md §13) ------------------------------------
    def _start_prefill(self, handle: Handle, row: int) -> None:
        """Claim slot ``row`` as a PREFILLING task: set up the chunk state
        (a live-arena fused path unsharded-paged; a private dense state
        otherwise), splice any prefix hit, and reserve pages — one chunk at
        a time on the fused path, the whole prompt up front when the blocks
        accumulate in a private state (they only reach pages at the finish
        splice).  The budget loop advances the task between decode steps."""
        forced = self._forced(handle)
        n = len(forced)
        hit = handle.__dict__.pop("_hit_pages", [])
        j = len(hit)
        t0 = time.monotonic()
        if handle._t_first is None:
            handle._t_first = t0
            self._h_queue.observe(t0 - handle._t_submit)
        if self._tr is not None:
            self._tr.emit("prefill_start", req=handle.id, t=t0, row=row,
                          hit_blocks=j, forced_tokens=n)
            if j:
                self._tr.emit("prefix_hit", req=handle.id, blocks=j)
        fused = self.paged and self.mesh is None
        if fused:
            state = None
            if j:
                self.pool.retain(hit)  # the row's own references to the hit
        elif j:
            self.pool.retain(hit)
            seed = np.full(self._spec0.n_blocks, -1, np.int64)
            seed[:j] = hit
            state = self._gather(self.state, jnp.asarray(seed, jnp.int32),
                                 jnp.int32(j))
        else:
            state = self._fresh()
        if self.paged:
            T, nb = self._spec0.block_size, self._spec0.n_blocks
            pages = np.full(nb, -1, np.int64)
            pages[:j] = hit
            if not fused:
                occupied = min(n // T, nb)
                if occupied > j:
                    pages[j:occupied] = self._alloc(occupied - j, row)
            self._pt_host[row] = pages
        if self.prefix_mode:
            if self._share:
                self._pfx["lookups"].inc()
            if j:
                self._pfx["hits"].inc()
                self._pfx["hit_blocks"].inc(j)
                self._pfx["reused_tokens"].inc(j * self._spec0.block_size)
            if handle._toks:
                self._pfx["resumes"].inc()
                self._pfx["resume_reused_blocks"].inc(j)
        self._prefill_tasks[row] = _PrefillTask(
            handle=handle, row=row, forced=forced, n=n,
            pos=j * self._chunk_t, hit=hit, state=state)
        self._seq += 1
        self._row_seq[row] = self._seq  # age ordering covers PREFILLING rows
        # Hygiene: the vacated slot keeps (garbage-)decoding until the
        # finish installs it; pin its host vectors to something inert.
        self._cur[row] = self.scfg.pad_id
        self._pos[row] = 0

    def _ensure_chunk_page(self, task: _PrefillTask, pos: int) -> bool:
        """Fused path: the full chunk at ``pos`` flushes one block — make
        sure its ring slot has a physical page before the chunk runs.  Same
        reclaim ladder as the decode sweep (``_ensure_pages``): reuse an
        exclusive page in place on ring wrap, allocate, evict cold index
        blocks, then preempt the youngest same-shard page holder.  Returns
        False when the reclaim preempted THIS task."""
        T, nb = self._spec0.block_size, self._spec0.n_blocks
        row = task.row
        slot = (pos // T) % nb
        shard = self._row_shard(row)
        while True:
            existing = int(self._pt_host[row, slot])
            if existing >= 0 and self.pool.refcount(existing) == 1:
                return True  # ring wrap: overwrite our exclusive page
            if self._shard_free(shard):
                page = self._alloc(1, row)[0]
                if existing >= 0:  # shared: only exists in prefix mode
                    self.pool.release([existing])
                    if self.prefix_mode:
                        self._pfx["cow_breaks"].inc()
                        if self._tr is not None:
                            self._tr.emit("cow_break", req=task.handle.id,
                                          row=row, slot=slot, page=existing)
                self._pt_host[row, slot] = page
                if self._tr is not None:
                    self._tr.emit("page_assign", req=task.handle.id,
                                  row=row, slot=slot, page=page)
                return True
            if self._share:
                ev = self._index_for(row).evict(self._shard_pool(row), 1)
                if ev:
                    if self._tr is not None:
                        self._tr.emit("prefix_evict", blocks=ev)
                    continue
            victim = next(
                (r for r in reversed(self._live_rows_by_age())
                 if self._row_shard(r) == shard
                 and (self._pt_host[r] >= 0).any()), None)
            if victim is None:
                raise RuntimeError("pool exhausted with no reclaimable pages")
            self._preempt(victim)
            if victim == row:
                return False

    def _advance_task(self, task: _PrefillTask, budget: int) -> int:
        """Run whole chunks of one PREFILLING task until the budget is
        spent, the task finishes, or a page reclaim preempts it.  Chunks
        are never split, so a task consumes budget in block_size units
        (plus one sub-block finishing tail).  Returns tokens processed."""
        T = self._chunk_t
        handle, row = task.handle, task.row
        spent = 0
        t0 = time.monotonic()
        while row in self._prefill_tasks:
            pos = task.pos
            C = min(T, task.n - pos)
            if spent + C > budget:
                break
            fused = task.state is None and self.paged
            # Multi-chunk fast path: when the budget covers several full
            # chunks, bucket the trip count to a power of two (bounded jit
            # cache) and run them as ONE scan dispatch.  The fused path
            # ensures a physical page per block up front and keeps the
            # finishing chunk out of the scan (install_chunk_row needs the
            # final view in its own trace).
            k = min((budget - spent) // T, (task.n - pos) // T, 8)
            if fused:
                k = min(k, (task.n - pos - 1) // T, self._spec0.n_blocks)
            kb = 1
            while kb * 2 <= k:
                kb *= 2
            if kb >= 2:
                if fused and not all(self._ensure_chunk_page(task, pos + j * T)
                                     for j in range(kb)):
                    break  # the reclaim preempted this very task
                tc = time.monotonic() if self._tr is not None else 0.0
                t = jnp.asarray(
                    task.forced[pos:pos + kb * T].reshape(kb, 1, T))
                if fused:
                    pages = jnp.asarray(self._pt_host[row], jnp.int32)
                    tok, self.state = self._chunk_paged_scan(
                        self.params, t, jnp.int32(pos), self.state, pages)
                else:
                    tok, task.state = self._chunk_scan(
                        self.params, t, jnp.int32(pos), task.state)
                if self._tr is not None:
                    self._tr.emit("prefill_chunk", req=handle.id, t=tc,
                                  dur=time.monotonic() - tc, row=row,
                                  pos=pos, tokens=kb * T, chunks=kb)
                task.pos = pos + kb * T
                task.chunks += kb
                spent += kb * T
                self._pf["chunks"].inc(kb)
                if self.prefix_mode:
                    self._pfx["prefill_tokens"].inc(kb * T)
                    self._pfx["prefill_attn_pairs"].inc(sum(
                        T * (pos + j * T) + T * (T + 1) // 2
                        for j in range(kb)))
                if task.pos == task.n:
                    self._finish_task(task, int(np.asarray(tok)[0]))
                    break
                continue
            if fused and C == T and not self._ensure_chunk_page(task, pos):
                break  # the reclaim preempted this very task
            tc = time.monotonic() if self._tr is not None else 0.0
            t = jnp.asarray(task.forced[None, pos:pos + C])
            if fused:
                pages = jnp.asarray(self._pt_host[row], jnp.int32)
                if pos + C == task.n:
                    tok, self.state = self._finish_paged(
                        self.params, t, jnp.int32(pos), self.state, pages,
                        jnp.int32(row))
                else:
                    tok, self.state = self._chunk_paged(
                        self.params, t, jnp.int32(pos), self.state, pages)
            else:
                tok, task.state = self._chunk(self.params, t, jnp.int32(pos),
                                              task.state)
            if self._tr is not None:
                self._tr.emit("prefill_chunk", req=handle.id, t=tc,
                              dur=time.monotonic() - tc, row=row,
                              pos=pos, tokens=C, chunks=1)
            task.pos = pos + C
            task.chunks += 1
            spent += C
            self._pf["chunks"].inc()
            if self.prefix_mode:
                self._pfx["prefill_tokens"].inc(C)
                self._pfx["prefill_attn_pairs"].inc(
                    C * pos + C * (C + 1) // 2)
            if task.pos == task.n:
                self._finish_task(task, int(np.asarray(tok)[0]))
                break
        handle._prefill_s += time.monotonic() - t0
        self._pf["prefill_tokens"].inc(spent)
        return spent

    def _finish_task(self, task: _PrefillTask, first: int) -> None:
        """The finishing chunk ran: the row's cache holds all ``n`` forced
        tokens and ``first`` is the next greedy token.  Promote the task to
        a live decode slot (fused: the finish chunk already installed the
        device row; dense-state: splice now), or retire immediately on a
        budget of 1 / instant EOS."""
        handle, row = task.handle, task.row
        del self._prefill_tasks[row]
        if handle._t_start is None:
            handle._t_start = time.monotonic()
        if self._tr is not None:
            self._tr.emit("prefill_finish", req=handle.id, row=row,
                          chunks=task.chunks)
        fused = task.state is None and self.paged
        if handle._push(first):
            # Finished at admission: the slot stays free.  The fused path
            # already installed the device row, so clear it; pages (and any
            # hit references) release either way.  Index insert is skipped,
            # matching solo admission — nothing else rides on this prompt.
            if self.paged:
                self._release_row(row)
            return
        if self.paged:
            T, nb = self._spec0.block_size, self._spec0.n_blocks
            n_full = task.n // T
            pages = self._pt_host[row]
            if not fused:
                self.state = self._insert(self.state, task.state, row,
                                          jnp.asarray(pages, jnp.int32))
            if self._share and n_full and n_full <= nb:
                self._index_for(row).insert(task.forced,
                                            pages[:n_full].tolist(),
                                            self.pool)
        else:
            self.state = self._insert(self.state, task.state, row)
        self._slots[row] = handle
        self._cur[row] = first
        self._pos[row] = task.n

    def _run_prefill_budget(self, budget: int, decoding: bool) -> int:
        """Spend (part of) this step's ``prefill_chunk_tokens`` across the
        carried-over PREFILLING rows, oldest admission first — finished
        tasks join the decode batch THIS step, so admission costs zero
        extra decode latency beyond the chunk compute itself.  Returns the
        unspent budget (the admission sweep hands it to new tasks)."""
        for row in sorted(self._prefill_tasks,
                          key=lambda r: self._row_seq[r]):
            if budget < 1:
                break
            task = self._prefill_tasks.get(row)
            if task is None:
                continue  # preempted by an earlier task's page reclaim
            spent = self._advance_task(task, budget)
            budget -= spent
            if decoding:
                self._pf["coscheduled_tokens"].inc(spent)
        return budget

    def _can_admit(self, handle: Handle, row: int) -> bool:
        """Memory-pressure admission (paged): the prompt's blocks plus one
        page of decode headroom must be free ON THE ROW'S DATA SHARD — NOT
        the request's whole lifetime, which is what lets slots
        oversubscribe; the preemption path covers over-commitment later.
        Prefix mode looks up the row's shard's index, discounts the hit
        blocks (they are spliced, not prefilled) and evicts cold index
        blocks before parking the queue head."""
        if not self.paged:
            return True
        shard_pool = self._shard_pool(row)
        if self.prefix_mode or self.prefill_unified:
            spec = self._spec0
            T, nb = spec.block_size, spec.n_blocks
            forced = self._forced(handle)
            n_full = len(forced) // T
            hit: list[int] = []
            if self._share and n_full <= nb:
                # Cap below the forced length so at least one token is left
                # to process — the last token's logits drive the next one.
                hit = self._index_for(row).lookup(
                    forced, min((len(forced) - 1) // T, nb))
            handle._hit_pages = hit  # the chunked admission splices this hit
            occupied = min(n_full, nb)
            if self.prefill_chunked and self.mesh is None and self.paged:
                # Fused chunking allocates pages one chunk ahead of the
                # flush, so admission only needs the FIRST step's chunks
                # plus decode headroom — a long prompt can start before
                # the pool could hold its dense form (the reclaim ladder
                # covers the rest of its lifetime).
                first = min(self._chunk_budget // T,
                            max(occupied - len(hit), 0))
                need = min(first + 1, shard_pool.n_pages)
            else:
                need = min(max(occupied - len(hit), 0) + 1,
                           shard_pool.n_pages)
            if shard_pool.free_pages < need and self._share:
                # Reclaim cold index blocks before giving up; the hit path
                # was just MRU-stamped AND is protected explicitly (its
                # pages are not yet retained by the row).
                self._index_for(row).evict(shard_pool, need, protect=hit)
            return shard_pool.free_pages >= need
        need = min(self._prefill_pages(handle.request) + 1, shard_pool.n_pages)
        return shard_pool.free_pages >= need

    def _pop_next(self) -> Handle:
        if self.scfg.policy == "ljf":
            # Direct index scan + del (the old double-rotate walked the
            # deque twice).  max() keeps the FIRST maximum, so equal-budget
            # requests still leave in arrival order.
            pick = max(range(len(self._queue)),
                       key=lambda i: self._queue[i].request.max_new_tokens)
            h = self._queue[pick]
            del self._queue[pick]
            return h
        return self._queue.popleft()

    # -- paged page-fault sweep / preemption ----------------------------------
    def _live_rows_by_age(self) -> list[int]:
        """Decoding AND prefilling rows, oldest admission first — both hold
        pages, so both are preemption candidates for the reclaim ladders."""
        rows = [r for r, s in enumerate(self._slots) if s is not None]
        rows += list(self._prefill_tasks)
        return sorted(rows, key=lambda r: self._row_seq[r])

    def _release_row(self, row: int) -> None:
        """Drop the row's references on its pages (a page shared with the
        prefix index or another row survives; an exclusive one is freed) and
        unassign its device page-table row, so the slot's continuing
        (garbage) decode can never write into pages that get re-issued to
        another request."""
        held = self._pt_host[row][self._pt_host[row] >= 0]
        if len(held):
            self.pool.release(held.tolist())
        self._pt_host[row] = -1
        self.state = self._clear(self.state, jnp.int32(row))

    def _preempt(self, row: int) -> None:
        """Evict a live request and requeue it at the queue head.

        Classic paged mode frees the pages and clears the generated tokens;
        re-admission replays the prompt (solo prefill) and greedy decode
        regenerates the identical tokens, so results — and even an
        in-flight ``Handle.tokens()`` stream — are unaffected beyond
        latency.  Prefix mode instead PARKS the progress: the row's flushed
        blocks (prompt and generated alike) are inserted into the index
        (sharing on), its generated tokens are kept, and the row's own page
        references drop — re-admission restores from the cached pages and
        chunk-prefills only the unflushed tail, no prompt replay.

        A half-prefilled row (DESIGN.md §13) preempts the same way, minus
        the device work: its page-table row was never installed, so only
        the host mirror releases.  On the fused path the flushed blocks
        already live in arena pages — sharing mode parks them in the index
        and the re-admission's lookup resumes from them; the dense-state
        path's blocks never reached pages (they die with the private chunk
        state), so nothing is parked and re-admission re-chunks."""
        task = self._prefill_tasks.pop(row, None)
        if task is not None:
            handle = task.handle
            if self._share and task.state is None:
                # task.pos is block-aligned mid-prefill: every full chunk
                # so far flushed its block into this row's pages.
                flushed = task.pos // self._spec0.block_size
                if 0 < flushed <= self._spec0.n_blocks:
                    self._index_for(row).insert(
                        task.forced, self._pt_host[row][:flushed].tolist(),
                        self.pool)
            held = self._pt_host[row][self._pt_host[row] >= 0]
            if len(held):
                self.pool.release(held.tolist())
            self._pt_host[row] = -1
            if not self.prefix_mode:
                handle._toks.clear()
            self._queue.appendleft(handle)
            self._preemptions.inc()
            self._pf["prefill_preemptions"].inc()
            self._preempt_by_shard[self._row_shard(row)].inc()
            if self._tr is not None:
                self._tr.emit("preempt", req=handle.id, row=row,
                              prefilling=True)
            return
        handle = self._slots[row]
        self._slots[row] = None
        if self.prefix_mode:
            if self._share:
                nb = self._spec0.n_blocks
                # Cache holds _pos tokens (the freshly pushed one is fed
                # next step), so flushed = _pos // T — insertable only while
                # the ring has not wrapped (slot i still holds block i).
                flushed = int(self._pos[row]) // self._spec0.block_size
                if 0 < flushed <= nb:
                    self._index_for(row).insert(
                        self._forced(handle),
                        self._pt_host[row][:flushed].tolist(),
                        self.pool)
            self._release_row(row)
        else:
            self._release_row(row)
            handle._toks.clear()
        self._queue.appendleft(handle)
        self._preemptions.inc()
        self._preempt_by_shard[self._row_shard(row)].inc()
        if self._tr is not None:
            self._tr.emit("preempt", req=handle.id, row=row,
                          prefilling=False)

    def _ensure_pages(self) -> None:
        """Assign a physical page to every live row whose buffer flushes on
        the NEXT decode step (the write path drops unassigned slots, so the
        page must exist before the flush).  Ring wraparound (sliding-window
        specs) reuses the slot's existing page in place — block-aligned
        eviction costs no allocation.  On exhaustion the youngest request is
        preempted until the flush fits; submit() guarantees any request can
        run solo, so the sweep always terminates with the oldest progressing.
        """
        T, nb = self._spec0.block_size, self._spec0.n_blocks
        rows_u, slots_u, pages_u = [], [], []
        for row in self._live_rows_by_age():
            if self._slots[row] is None:
                continue  # preempted earlier in this sweep
            pos = int(self._pos[row])
            if (pos + 1) % T:
                continue  # this step only appends to the raw buffer
            slot = ((pos + 1) // T - 1) % nb
            # Unassigned slot, or a copy-on-write break: the ring wrapped
            # onto a page the prefix index / another row still references —
            # the flush overwrites the whole block, so "copy" degenerates
            # to re-pointing the slot at a private page and dropping our
            # reference on the shared one.
            shard = self._row_shard(row)
            while True:
                existing = int(self._pt_host[row, slot])
                if existing >= 0 and self.pool.refcount(existing) == 1:
                    break  # SWA ring reuse: overwrite our exclusive page
                if self._shard_free(shard):
                    page = self._alloc(1, row)[0]
                    if existing >= 0:  # shared: only exists in prefix mode
                        self.pool.release([existing])
                        self._pfx["cow_breaks"].inc()
                        if self._tr is not None:
                            self._tr.emit(
                                "cow_break", req=self._slots[row].id,
                                row=row, slot=slot, page=existing)
                    self._pt_host[row, slot] = page
                    if self._tr is not None:
                        self._tr.emit("page_assign", req=self._slots[row].id,
                                      row=row, slot=slot, page=page)
                    rows_u.append(row)
                    slots_u.append(slot)
                    pages_u.append(page)
                    break
                # Reclaim cold prefix-index blocks first (cheap: nothing
                # loses progress).  Progress = blocks evicted, not pages
                # freed: releasing the index's reference on THIS row's own
                # shared page makes it exclusive, and the re-check above
                # then reuses it in place — without that re-check a solo
                # row whose pages the index shares would preempt itself.
                # Then preempt the youngest SAME-SHARD row that actually
                # HOLDS pages (only same-shard pages relieve this row's
                # pressure; a zero-page victim would destroy progress
                # without freeing a byte).  Each round frees a page, evicts
                # an index block, or shrinks the shard's live rows, so the
                # loop terminates — submit() guaranteed the row fits its
                # shard solo.
                if self._share:
                    ev = self._index_for(row).evict(self._shard_pool(row), 1)
                    if ev:
                        if self._tr is not None:
                            self._tr.emit("prefix_evict", blocks=ev)
                        continue
                victim = next(
                    (r for r in reversed(self._live_rows_by_age())
                     if self._row_shard(r) == shard
                     and (self._pt_host[r] >= 0).any()), None)
                if victim is None:
                    raise RuntimeError(
                        "pool exhausted with no reclaimable pages")
                self._preempt(victim)
                if victim == row:
                    break
        # A later row's victim scan can preempt a row recorded EARLIER in
        # this sweep (the younger row may hold zero pages, making an older,
        # already-granted row the youngest page holder).  That row's pages
        # — including the one just recorded — are back in the free list and
        # may already be re-issued to a following row, so its stale triple
        # must not re-point the cleared device row: its full-buffer garbage
        # flush would land in another request's page this very step.
        live = [(r, s, p) for r, s, p in zip(rows_u, slots_u, pages_u)
                if self._slots[r] is not None]
        if live:
            rows_u, slots_u, pages_u = map(list, zip(*live))
            B = self.scfg.max_slots
            pad = B - len(rows_u)
            self.state = self._assign(
                self.state,
                jnp.asarray(rows_u + [-1] * pad, jnp.int32),
                jnp.asarray(slots_u + [0] * pad, jnp.int32),
                jnp.asarray(pages_u + [0] * pad, jnp.int32))

    def step(self) -> bool:
        """Admit whatever fits (slot- AND, in paged mode, memory-pressure-
        bounded), then run one batched decode step over the live slots.
        Returns True while work remains (active or queued)."""
        if self.mesh is not None:
            # Re-assert trace-time context before any closure compiles a
            # new shape (another Server may have rebound it since __init__).
            from repro.distributed import serve_shard
            serve_shard.set_serve_mesh(self.mesh, self._inner_backend)
        free = [i for i, s in enumerate(self._slots)
                if s is None and i not in self._prefill_tasks]
        decoding = any(s is not None for s in self._slots)
        # Chunked admission: carried-over PREFILLING tasks spend the step's
        # prompt-token budget FIRST (they are older than anything admitted
        # this sweep); new admissions below chunk through whatever is left.
        # A task finishing here joins the decode batch this very step — and
        # inserts its blocks into the prefix index BEFORE the sweep's next
        # lookup, so co-arriving shared prompts still reuse each other.
        pf_budget = self._chunk_budget if self.prefill_chunked else 0
        if self._prefill_tasks:
            pf_budget = self._run_prefill_budget(pf_budget, decoding)
        while free and self._queue:
            handle = self._pop_next()
            # Admit onto the free slot whose data shard has the most free
            # pages (slots pin rows to shards, pages are shard-local);
            # stable tie-break keeps this exactly free[0] when unsharded.
            if self.paged and self._n_data > 1:
                row = min(free, key=lambda r:
                          (-self._shard_free(self._row_shard(r)), r))
            else:
                row = free[0]
            if not self._can_admit(handle, row):
                # Pool pressure: park it until retirements free pages.
                self._queue.appendleft(handle)
                break
            if self.prefill_unified:
                self._start_prefill(handle, row)
                free.remove(row)
                task = self._prefill_tasks.get(row)
                if not self.prefill_chunked:
                    # Solo mode, unified numerics: drain every chunk right
                    # here — the admission stall the chunked default kills,
                    # kept as the explicit baseline (bit-identical tokens).
                    if decoding:
                        self._pf["stalled_decode_steps"].inc()
                    if task is not None:
                        self._advance_task(task, task.n)
                elif task is not None and pf_budget >= 1:
                    spent = self._advance_task(task, pf_budget)
                    pf_budget -= spent
                    if decoding:
                        self._pf["coscheduled_tokens"].inc(spent)
                if self._queue and self._queue[0] is handle:
                    break  # the chunk loop preempted itself: pool too tight
                if (row not in self._prefill_tasks
                        and self._slots[row] is None):
                    free.append(row)  # finished (and retired) at admission
            elif self._admit(handle, row):
                free.remove(row)
        if self.paged:
            self._ensure_pages()
        rows = [i for i, s in enumerate(self._slots) if s is not None]
        if not rows:
            return bool(self._queue) or bool(self._prefill_tasks)
        full = self._tr is not None and self._tr.full
        td = time.monotonic() if full else 0.0
        toks, self.state = self._decode(
            self.params, jnp.asarray(self._cur), jnp.asarray(self._pos),
            self.state)
        nxt = np.asarray(toks)
        if full:
            # "full" firehose: one span per batched decode dispatch (the
            # np.asarray above synced it, so dur covers device time too).
            self._tr.emit("decode_step", t=td, dur=time.monotonic() - td,
                          rows=len(rows))
        for row in rows:
            tok = int(nxt[row])
            self._cur[row] = tok
            self._pos[row] += 1
            if self._slots[row]._push(tok):
                self._slots[row] = None  # retire; slot reused next step
                if self.paged:
                    self._release_row(row)
        return (bool(self._queue) or bool(self._prefill_tasks)
                or any(s is not None for s in self._slots))

    def run(self) -> None:
        """Drain: step until every submitted request has finished."""
        while self.step():
            pass

    def memory_report(self) -> dict:
        """Measured bytes of the live decode state (all slots)."""
        return cache_memory_report(self.cfg, self.state)

    def stats(self) -> dict:
        """The documented serving-stats tree (DESIGN.md §14) — a view over
        ``self.metrics``.  ONE schema regardless of sharding: the key tree
        depends only on (cache_mode, prefix_cache), never on the mesh —
        ``shards``/``per_shard`` are always present (one entry unsharded)
        and ``pool`` (paged) always carries aggregate + ``per_shard``.
        ``tests/test_obs.py`` pins the exact tree."""
        self._g_active.set(self.active)
        self._g_pending.set(self.pending)
        s = {
            "cache_mode": "paged" if self.paged else "dense",
            "active": self.active,
            "pending": self.pending,
            "preemptions": self._preemptions.value,
            # Admission observability (DESIGN.md §13): chunks in flight,
            # prompt tokens co-scheduled with live decoders, and how often
            # solo admissions stalled a live batch (0 by design chunked).
            "prefill": {
                "mode": "chunked" if self.prefill_chunked else "solo",
                "chunk_tokens": self._chunk_budget,
                "prefilling": len(self._prefill_tasks),
                "inflight_tokens": sum(t.n - t.pos
                                       for t in self._prefill_tasks.values()),
                **{k: c.value for k, c in self._pf.items()},
            },
            # Histogram-derived serving latency (submit-relative TTFT,
            # inter-token gaps, queue wait) — the registry's summaries, so
            # bench scripts stop re-deriving them from Result lists.
            "latency": {
                "ttft_s": self._h_ttft.snapshot(),
                "itl_s": self._h_itl.snapshot(),
                "queue_wait_s": self._h_queue.snapshot(),
            },
            "trace": {
                "level": self.trace.level,
                "events": len(self.trace.events),
                "dropped": self.trace.dropped,
            },
        }
        per_pool = None
        if self.paged:
            per_pool = (self.pool.shard_stats() if self._n_data > 1
                        else [self.pool.stats()])
            # Aggregate pool occupancy + the per-shard breakdown: the same
            # two-level shape whether the arena is sharded or not (one
            # entry covering the whole pool unsharded).
            s["pool"] = {**self.pool.stats(), "per_shard": per_pool}
        s["shards"] = {
            "n_data": self._n_data,
            "n_model": self._n_model,
            "per_shard": [
                {"preemptions": self._preempt_by_shard[d].value,
                 **({"pages_live": per_pool[d]["pages_live"],
                     "pages_free": per_pool[d]["pages_free"],
                     "high_water_pages": per_pool[d]["high_water_pages"]}
                    if per_pool is not None else {})}
                for d in range(self._n_data)],
        }
        if self.prefix_mode:
            p = {k: c.value for k, c in self._pfx.items()}
            p["mode"] = self.scfg.prefix_cache
            p["hit_rate"] = (p["hits"] / p["lookups"]) if p["lookups"] else 0.0
            if self._share:
                from repro.serve.prefix import PrefixIndex
                p["index"] = PrefixIndex.merge_stats(self._indexes)
            s["prefix"] = p
        return s

    def shutdown(self, metrics_out=None, trace_out=None) -> dict:
        """Export final telemetry and return the snapshot (DESIGN.md §14).

        ``metrics_out`` writes the JSON snapshot (``stats()`` tree plus the
        raw registry dump) and a Prometheus text exposition next to it
        (``<metrics_out>.prom`` sibling with the suffix swapped);
        ``trace_out`` writes the Chrome trace-event JSON (only when tracing
        was on) — load it at ui.perfetto.dev for per-request tracks.  The
        server stays usable afterwards; "shutdown" names the serving
        lifecycle hook, not a teardown.
        """
        snap = {"stats": self.stats(), "metrics": self.metrics.snapshot()}
        if metrics_out:
            out = Path(metrics_out)
            out.write_text(json.dumps(snap, indent=2, default=float))
            out.with_suffix(".prom").write_text(
                self.metrics.prometheus_text())
        if trace_out and self.trace.enabled:
            self.trace.write_chrome(trace_out)
        return snap


def cache_memory_report(cfg: ModelConfig, state) -> dict:
    """Measured bytes of a decode state per layout — the serving-side
    memory-reduction claim, computed from the actual arrays.

    Under a per-layer ``CompressionPolicy`` the KV entry also lists each
    layer's resolved layout (the caches live in a tuple, one spec each).
    """
    tot = 0
    kv = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        nbytes = leaf.size * leaf.dtype.itemsize
        tot += nbytes
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "kv" in keys:
            kv += nbytes
    rep = {"total_bytes": int(tot), "kv_bytes": int(kv),
           "layout": cfg.cache_layout}
    caches = state.get("kv") if isinstance(state, dict) else None
    if isinstance(caches, (tuple, list)):
        rep["per_layer_layouts"] = [c.spec.layout for c in caches]
    return rep
