"""Serving engine: batched generation over compressed KV caches.

The paper's KVCompCache integration point (§4.2: "we implemented a
KVCompCache class … efficiently integrated with all supported models") —
here the cache IS the decode state, and compression runs on the hot path:
prefill bulk-compresses the prompt KV (Store), every decode step appends to
the block buffer and flushes compressed blocks (Store), and attention
consumes packed blocks (Fetch).

Scheduling: requests are grouped into length buckets (right-aligned to a
bucket grid) so every batch shares one prompt length — the uniform-length
contract of the cache (DESIGN.md §5).  A bucket forms a generation group
that decodes in lockstep until all members finish (EOS or max tokens);
finished rows keep decoding but their outputs are masked (standard
continuous-batching-with-buckets simplification).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [S]
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Result:
    tokens: np.ndarray
    prompt_len: int
    gen_s: float
    prefill_s: float


@dataclasses.dataclass
class EngineConfig:
    bucket: int = 64          # prompt lengths padded up to a multiple
    max_batch: int = 8
    max_seq: int = 4096
    greedy: bool = True
    pad_id: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 q_chunk: int = 512, kv_chunk: int = 512):
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, ecfg.max_seq,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk))
        self._decode = jax.jit(
            lambda p, t, pos, st: M.decode_step(p, cfg, t, pos, st))

    # -- scheduling -----------------------------------------------------------
    def _buckets(self, reqs: list[Request]) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            b = -(-len(r.prompt) // self.ecfg.bucket) * self.ecfg.bucket
            out.setdefault(b, []).append(i)
        return out

    def generate(self, reqs: list[Request]) -> list[Result]:
        results: list[Result | None] = [None] * len(reqs)
        for blen, idxs in self._buckets(reqs).items():
            for off in range(0, len(idxs), self.ecfg.max_batch):
                group = idxs[off : off + self.ecfg.max_batch]
                self._run_group(reqs, group, blen, results)
        return results  # type: ignore[return-value]

    def _run_group(self, reqs, group, blen, results):
        B = len(group)
        prompts = np.full((B, blen), self.ecfg.pad_id, np.int32)
        lens = np.zeros(B, np.int64)
        for j, i in enumerate(group):
            p = reqs[i].prompt
            prompts[j, blen - len(p):] = p  # left-pad into the bucket
            lens[j] = len(p)
        t0 = time.monotonic()
        logits, state = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        t1 = time.monotonic()
        max_new = max(reqs[i].max_new_tokens for i in group)
        toks = np.zeros((B, max_new), np.int32)
        done = np.zeros(B, bool)
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = blen
        for t in range(max_new):
            toks[:, t] = np.asarray(cur)
            for j, i in enumerate(group):
                if reqs[i].eos_id is not None and toks[j, t] == reqs[i].eos_id:
                    done[j] = True
                if t + 1 >= reqs[i].max_new_tokens:
                    done[j] = True
            if done.all():
                break
            logits, state = self._decode(self.params, cur,
                                         jnp.asarray(pos, jnp.int32), state)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
        t2 = time.monotonic()
        for j, i in enumerate(group):
            n = reqs[i].max_new_tokens
            results[i] = Result(tokens=toks[j, :n], prompt_len=int(lens[j]),
                                gen_s=t2 - t1, prefill_s=t1 - t0)


def cache_memory_report(cfg: ModelConfig, state) -> dict:
    """Measured bytes of the decode state per layout — the serving-side
    memory-reduction claim, computed from the actual arrays.

    Under a per-layer ``CompressionPolicy`` the KV entry also lists each
    layer's resolved layout (the caches live in a tuple, one spec each).
    """
    tot = 0
    kv = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        nbytes = leaf.size * leaf.dtype.itemsize
        tot += nbytes
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "kv" in keys:
            kv += nbytes
    rep = {"total_bytes": int(tot), "kv_bytes": int(kv),
           "layout": cfg.cache_layout}
    caches = state.get("kv") if isinstance(state, dict) else None
    if isinstance(caches, (tuple, list)):
        rep["per_layer_layouts"] = [c.spec.layout for c in caches]
    return rep
