"""Serving engine: batched generation over compressed KV caches.

``Engine`` is now a thin compatibility wrapper over the continuous-batching
``repro.serve.scheduler.Server`` (the paper's KVCompCache integration point,
§4.2, behind a Server/Session API): ``generate(reqs)`` submits every request
and drains the slot scheduler, so heterogeneous prompt lengths and token
budgets decode concurrently with no bucket padding, results carry
**per-request** timing, and tokens are truncated at ``eos_id``.

``LockstepEngine`` preserves the pre-scheduler behaviour — length-bucketed
groups decoding in lockstep until the whole group finishes — as the measured
baseline for ``benchmarks/serve_throughput.py``.  Do not use it for new
code; it exists so the continuous-batching win stays an apples-to-apples
number instead of folklore.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve.scheduler import (  # noqa: F401  (re-exported compat names)
    Request, Result, Server, ServerConfig, cache_memory_report)


@dataclasses.dataclass
class EngineConfig:
    bucket: int = 64          # legacy: LockstepEngine's prompt-length grid
    max_batch: int = 8        # concurrent slots (Server) / group size (legacy)
    max_seq: int = 4096
    greedy: bool = True
    pad_id: int = 0
    # Decode-attention backend override (None = model config's attn_backend).
    attn_backend: str | None = None


class Engine:
    """Compat facade: ``generate(list[Request]) -> list[Result]`` on top of
    the Server/Session API.  Requests join and leave decode slots mid-flight;
    ``ecfg.bucket`` is accepted but unused (no bucketing remains)."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 q_chunk: int = 512, kv_chunk: int = 512):
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.server = Server(
            cfg, params,
            ServerConfig(max_slots=ecfg.max_batch, max_seq=ecfg.max_seq,
                         greedy=ecfg.greedy, pad_id=ecfg.pad_id,
                         attn_backend=ecfg.attn_backend),
            q_chunk=q_chunk, kv_chunk=kv_chunk)

    def generate(self, reqs: list[Request]) -> list[Result]:
        handles = [self.server.submit(r) for r in reqs]
        return [h.result() for h in handles]


class LockstepEngine:
    """The legacy bucket batcher (benchmark baseline only).

    Requests are grouped into length buckets (left-padded to a bucket grid)
    so every group shares one scalar position; a group decodes in lockstep
    for ``max(max_new_tokens)`` steps (finished rows keep burning masked
    steps) and new requests cannot join until the group drains.  Timing is
    group-shared and tokens are not truncated at EOS — faithfully the old
    behaviour, wasted work included.
    """

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 q_chunk: int = 512, kv_chunk: int = 512):
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, ecfg.max_seq,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk))
        self._decode = jax.jit(
            lambda p, t, pos, st: M.decode_step(p, cfg, t, pos, st))

    def _buckets(self, reqs: list[Request]) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            b = -(-len(r.prompt) // self.ecfg.bucket) * self.ecfg.bucket
            out.setdefault(b, []).append(i)
        return out

    def generate(self, reqs: list[Request]) -> list[Result]:
        results: list[Result | None] = [None] * len(reqs)
        for blen, idxs in self._buckets(reqs).items():
            for off in range(0, len(idxs), self.ecfg.max_batch):
                group = idxs[off : off + self.ecfg.max_batch]
                self._run_group(reqs, group, blen, results)
        return results  # type: ignore[return-value]

    def _run_group(self, reqs, group, blen, results):
        B = len(group)
        prompts = np.full((B, blen), self.ecfg.pad_id, np.int32)
        lens = np.zeros(B, np.int64)
        for j, i in enumerate(group):
            p = reqs[i].prompt
            prompts[j, blen - len(p):] = p  # left-pad into the bucket
            lens[j] = len(p)
        t0 = time.monotonic()
        logits, state = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        t1 = time.monotonic()
        max_new = max(reqs[i].max_new_tokens for i in group)
        toks = np.zeros((B, max_new), np.int32)
        done = np.zeros(B, bool)
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = blen
        for t in range(max_new):
            toks[:, t] = np.asarray(cur)
            for j, i in enumerate(group):
                if reqs[i].eos_id is not None and toks[j, t] == reqs[i].eos_id:
                    done[j] = True
                if t + 1 >= reqs[i].max_new_tokens:
                    done[j] = True
            if done.all():
                break
            logits, state = self._decode(self.params, cur,
                                         jnp.asarray(pos, jnp.int32), state)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
        t2 = time.monotonic()
        for j, i in enumerate(group):
            n = reqs[i].max_new_tokens
            results[i] = Result(tokens=toks[j, :n], prompt_len=int(lens[j]),
                                gen_s=t2 - t1, prefill_s=t1 - t0)
