"""Block-aligned prefix-cache index over compressed pages (DESIGN.md §11).

At millions-of-users scale most traffic shares long system prompts and
few-shot prefixes; KVComp makes prefix reuse strictly better than
vLLM-style raw-page sharing because each cached page holds ``block_size``
tokens at the 2-4x smaller post-compression footprint.  Since chunked
admission became the scheduler default (DESIGN.md §13) the index feeds a
single unified prefill path: a hit seeds the chunk loop at block ``j`` and
the remaining chunks run under the per-step budget, interleaved with
decode, with half-prefilled rows parking their flushed blocks back here on
preemption.  This module is the host-side index: a radix tree whose edges
are whole compression blocks
(``block_size`` token ids each) and whose nodes each own ONE physical page
of the ``repro.core.pool`` arena — the compressed encoding of that block,
valid for any request whose prompt walks the same token path from the root
(the block-chunked admission path makes equal-prefix pages bit-identical,
so a cached page and a recomputed one are interchangeable).

Node keys are the raw token bytes of the block, not hashes — two distinct
prefixes can never collide into one page.  Every node holds one pool
reference (``PagedBlockPool.retain`` on insert, ``release`` on eviction),
so a page stays live while EITHER the index or any row's page table points
at it, and dies only when the last reference drops.  Lookup and insert
stamp the touched path with a logical clock; eviction releases LRU *leaf*
blocks first (an inner block can never outlive its extensions — evicting a
parent before its children would break every cached path through it).

The index is pure host bookkeeping, like the pool allocator: the device
only ever sees page ids that the scheduler splices into page tables.
"""

from __future__ import annotations

import numpy as np

from repro.obs.metrics import Counter


class _Node:
    __slots__ = ("key", "page", "parent", "children", "stamp")

    def __init__(self, key: bytes, page: int, parent: "_Node | None"):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _Node] = {}
        self.stamp = 0


class PrefixIndex:
    """Radix tree: block-aligned token prefixes -> live arena page ids."""

    def __init__(self, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.block_size = int(block_size)
        self._children: dict[bytes, _Node] = {}  # root's children
        self._clock = 0
        self._n_blocks = 0
        # Typed metrics (DESIGN.md §14): standalone Counters, adopted under
        # ``prefix.index.*`` by the serving Server's MetricsRegistry.
        self.m_inserted_blocks = Counter()
        self.m_evicted_blocks = Counter()

    @property
    def inserted_blocks(self) -> int:
        return self.m_inserted_blocks.value

    @property
    def evicted_blocks(self) -> int:
        return self.m_evicted_blocks.value

    # -- internals ------------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _keys(self, tokens, n_blocks: int) -> list[bytes]:
        T = self.block_size
        t = np.ascontiguousarray(np.asarray(tokens, np.int32)[: n_blocks * T])
        return [t[i * T : (i + 1) * T].tobytes() for i in range(n_blocks)]

    def _leaves(self) -> list[_Node]:
        out, stack = [], list(self._children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    # -- queries / mutation ---------------------------------------------------
    def lookup(self, tokens, max_blocks: int) -> list[int]:
        """Longest cached block-aligned prefix of ``tokens``, capped at
        ``max_blocks``; returns its page ids in block order (possibly empty)
        and MRU-stamps the matched path so admission-pressure eviction never
        reclaims pages about to be spliced."""
        stamp = self._tick()
        pages: list[int] = []
        children = self._children
        for key in self._keys(tokens, max(int(max_blocks), 0)):
            node = children.get(key)
            if node is None:
                break
            node.stamp = stamp
            pages.append(node.page)
            children = node.children
        return pages

    def insert(self, tokens, pages, pool) -> int:
        """Index the first ``len(pages)`` blocks of ``tokens``; every newly
        created node retains its page in ``pool`` (the index's own
        reference).  Blocks already indexed keep their original page — by
        chunked-admission determinism both copies hold identical bits, and
        keeping the old one preserves existing sharers.  Returns the number
        of nodes created."""
        stamp = self._tick()
        created = 0
        parent: _Node | None = None
        children = self._children
        for key, page in zip(self._keys(tokens, len(pages)), pages):
            node = children.get(key)
            if node is None:
                node = _Node(key, int(page), parent)
                pool.retain([node.page])
                children[key] = node
                created += 1
                self._n_blocks += 1
                self.m_inserted_blocks.inc()
            node.stamp = stamp
            parent = node
            children = node.children
        return created

    def evict(self, pool, need_free: int, protect=()) -> int:
        """Release LRU leaf blocks until ``pool.free_pages >= need_free`` or
        nothing evictable remains.  ``protect`` is a set of page ids that
        must survive (a just-looked-up hit path whose pages are not yet
        retained by the admitting row).  Returns how many BLOCKS were
        evicted — the caller's progress signal.  An eviction does not
        always free a page (releasing a block a live row still references
        merely unshares it), but it always makes progress: the row's page
        becomes exclusively owned, so its next ring-wrap flush can reuse it
        in place instead of allocating."""
        protect = frozenset(int(p) for p in protect)
        evicted = 0
        while pool.free_pages < need_free:
            leaves = [n for n in self._leaves() if n.page not in protect]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.stamp)
            pool.release([victim.page])
            siblings = (victim.parent.children if victim.parent is not None
                        else self._children)
            del siblings[victim.key]
            self._n_blocks -= 1
            self.m_evicted_blocks.inc()
            evicted += 1
        return evicted

    def indexed_pages(self) -> list[int]:
        """Every node's retained page id (one pool reference each) — the
        index side of the ``InvariantAuditor``'s refcount balance
        (DESIGN.md §15)."""
        out, stack = [], list(self._children.values())
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out

    # -- reporting ------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        """Blocks (= nodes = retained pages) currently indexed."""
        return self._n_blocks

    def stats(self) -> dict:
        return {
            "blocks": self._n_blocks,
            "inserted_blocks": self.inserted_blocks,
            "evicted_blocks": self.evicted_blocks,
        }

    @staticmethod
    def merge_stats(indexes) -> dict:
        """Aggregate ``stats()`` across several indexes — the sharded server
        keeps one index per data shard (a prefix is only reusable by rows
        whose pages live on the same shard; DESIGN.md §12) but reports one
        combined prefix section."""
        per = [ix.stats() for ix in indexes]
        keys = per[0] if per else {"blocks": 0, "inserted_blocks": 0,
                                   "evicted_blocks": 0}
        return {k: sum(p[k] for p in per) for k in keys}
