"""Deterministic fault injection and invariant auditing for the serving
stack (DESIGN.md §15).

The scheduler/pool/prefix machinery grown across DESIGN.md §8–§13
(preemption, CoW page sharing, refcounted prefix pages, shard-affine
admission, chunked prefill) is complex enough that its invariants deserve
an adversarial harness, not only happy-path tests.  This module supplies
both halves:

* ``FaultPlan`` — a *seeded*, fully deterministic schedule of failures at
  named scheduler sites.  The Server consults ``plan.fire(site)`` at each
  decision point; a firing site makes the scheduler take its
  failure/reclaim path (an empty free list, a victimless reclaim sweep, a
  failing chunk dispatch, ...) without any real resource actually
  misbehaving.  Determinism is the contract: the same ``(seed, rates, at)``
  produce the same firing pattern in any process, so a chaos-soak failure
  replays exactly from its printed seed (``REPRO_CHAOS_SEED``).

* ``InvariantAuditor`` — cross-checks the Server's redundant bookkeeping
  after (periodically, or every step under test) each scheduler step:
  pool free/live partition and refcount balance against the page tables
  and the prefix index, host page-table mirror against the device tables,
  page/shard affinity, and slot/queue/task accounting.  A violation is
  reported with enough context to debug the step that introduced it; the
  accumulated ``report()`` is the artifact the CI chaos leg uploads on
  failure.

``ServeError`` lives here too: the lifecycle error the scheduler raises
when it can prove it is stuck (the no-progress detector, DESIGN.md §15) and
the base of ``QueueFull`` (bounded-admission rejection).  Keeping them in
this module lets ``scheduler.py`` import downward only.
"""

from __future__ import annotations

import zlib

import numpy as np

__all__ = [
    "FAULT_SITES", "FaultInjected", "FaultPlan",
    "InvariantAuditor", "InvariantViolation",
    "QueueFull", "ServeError",
]

# The named injection sites the scheduler consults (DESIGN.md §15).  Each
# names a *decision*, and firing it forces the pessimistic branch:
#
# ==================  ======================================================
# ``pool_alloc``      a free-page check reads 0 — the caller takes its
#                     reclaim ladder (evict index blocks, preempt) exactly
#                     as if the arena were full
# ``reclaim_sweep``   the preemption victim scan comes up empty — the
#                     terminal "pool exhausted with no reclaimable pages"
#                     path (requeue-with-backoff, then FAILED)
# ``prefix_evict``    a prefix-index eviction reclaims nothing this round
# ``prefix_insert``   parking/indexing flushed blocks is skipped (the pages
#                     release instead of entering the radix index)
# ``chunk_prefill``   a chunked-prefill dispatch fails before launching —
#                     the task's request is requeued (bounded) or failed
# ``decode_dispatch`` the batched decode dispatch fails transiently before
#                     launch — the step skips decoding and retries next
#                     step (state untouched, tokens merely delayed)
# ==================  ======================================================
FAULT_SITES = ("pool_alloc", "reclaim_sweep", "prefix_evict",
               "prefix_insert", "chunk_prefill", "decode_dispatch")


class ServeError(RuntimeError):
    """A request-lifecycle error the Server can attribute and explain —
    raised (not swallowed) because it reflects a caller-visible contract
    violation: a provably stuck server, or a rejected submit."""


class QueueFull(ServeError):
    """``Server.submit`` under ``ServerConfig.max_pending`` with the
    "reject" backpressure policy: the admission queue is at capacity."""


class FaultInjected(RuntimeError):
    """Marker for an injected failure (never escapes the Server)."""


class InvariantViolation(AssertionError):
    """The auditor found the Server's redundant bookkeeping disagreeing."""


class FaultPlan:
    """Seeded deterministic failure schedule over the named ``FAULT_SITES``.

    Two composable triggers per site:

    * ``at``    — exact 1-based visit indices: ``{"reclaim_sweep": (1, 3)}``
      fires the first and third time the scheduler consults that site.
    * ``rates`` — per-visit probability: ``{"pool_alloc": 0.05}`` fires each
      visit with p=0.05 from a per-site generator seeded by
      ``(seed, crc32(site))`` — stable across processes and runs, so a
      printed seed replays the identical schedule.

    ``fire(site)`` is the only hot-path call; ``fired`` records every
    (site, visit-index) that fired, which the chaos tests print on failure
    next to the seed.
    """

    def __init__(self, seed: int = 0, rates: dict | None = None,
                 at: dict | None = None):
        self.seed = int(seed)
        self.rates = {str(k): float(v) for k, v in (rates or {}).items()}
        self.at = {str(k): frozenset(int(i) for i in v)
                   for k, v in (at or {}).items()}
        for site in (*self.rates, *self.at):
            if site not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; sites are {FAULT_SITES}")
        for site, p in self.rates.items():
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], got {p}")
        self.visits = {s: 0 for s in FAULT_SITES}
        self.fired: list[tuple[str, int]] = []
        # One independent generator per site: firing order at one site can
        # never perturb another's schedule (determinism survives refactors
        # that reorder site consultations).
        self._rng = {s: np.random.default_rng((self.seed,
                                               zlib.crc32(s.encode())))
                     for s in self.rates}

    def fire(self, site: str) -> bool:
        """Count one visit to ``site`` and decide whether it faults."""
        n = self.visits[site] = self.visits[site] + 1
        hit = n in self.at.get(site, ())
        rate = self.rates.get(site, 0.0)
        if rate and self._rng[site].random() < rate:
            hit = True
        if hit:
            self.fired.append((site, n))
        return hit

    def stats(self) -> dict:
        return {"seed": self.seed,
                "visits": dict(self.visits),
                "fired": [list(f) for f in self.fired]}

    def __repr__(self):
        return (f"FaultPlan(seed={self.seed}, rates={self.rates}, "
                f"at={dict((k, sorted(v)) for k, v in self.at.items())})")


def _np_rows(a) -> np.ndarray:
    """Host copy of a (possibly sharded, possibly layer-stacked) device
    page table as ``int64 [L?, B, NB]``."""
    return np.asarray(a).astype(np.int64)


class InvariantAuditor:
    """Cross-checks a live ``Server``'s redundant bookkeeping.

    The Server keeps the same facts in several places on purpose — host
    page-table mirror vs device tables, pool refcounts vs the rows/index
    that hold the references, free-list vs live-set — because the device
    side must stay jit-friendly while the host side drives admission.  The
    auditor recomputes each fact from first principles and reports every
    disagreement (DESIGN.md §15):

    1.  **Pool partition** (per shard pool): ``free + live == n_pages``,
        the free list holds no duplicates and no live page, every live
        page has refcount >= 1.
    2.  **Refcount balance**: for every page, its pool refcount equals the
        number of row page-table entries referencing it (live *and*
        PREFILLING rows) plus the number of prefix-index nodes holding it.
    3.  **Aliasing / affinity**: no row references the same page twice; a
        row's pages all come from the row's own data shard's pool slice.
    4.  **Host/device page tables**: live decode rows' device rows equal
        the host mirror on every layer; PREFILLING and free rows are fully
        unassigned (-1) on device (the write-drop guarantee).
    5.  **Slot/queue/task accounting**: slots and prefill tasks are
        disjoint, no finished handle is still scheduled, no handle appears
        twice, ``pending`` matches the queue.

    ``audit()`` returns the violation list (empty = clean) and accumulates
    ``report()`` — the artifact the chaos CI leg uploads on failure;
    ``check()`` raises ``InvariantViolation`` with the full list.
    """

    def __init__(self, server):
        self.server = server
        self.audits = 0
        self.violations: list[str] = []

    # -- helpers --------------------------------------------------------------
    def _shard_pools(self) -> list:
        srv = self.server
        if srv.pool is None:
            return []
        return list(getattr(srv.pool, "shards", None) or [srv.pool])

    def _scheduled_rows(self) -> tuple[set, set]:
        srv = self.server
        live = {r for r, s in enumerate(srv._slots) if s is not None}
        return live, set(srv._prefill_tasks)

    # -- the audit ------------------------------------------------------------
    def audit(self) -> list[str]:
        srv = self.server
        bad: list[str] = []
        live_rows, task_rows = self._scheduled_rows()

        # 5. slot/queue/task accounting (valid in dense mode too)
        if live_rows & task_rows:
            bad.append(f"rows both decoding and prefilling: "
                       f"{sorted(live_rows & task_rows)}")
        seen: dict[int, str] = {}
        placements = (
            [(h, "queue") for h in srv._queue]
            + [(srv._slots[r], f"slot{r}") for r in live_rows]
            + [(t.handle, f"task{r}") for r, t in srv._prefill_tasks.items()])
        for h, where in placements:
            if h.id in seen:
                bad.append(f"req {h.id} scheduled twice: "
                           f"{seen[h.id]} and {where}")
            seen[h.id] = where
            if h.done:
                bad.append(f"req {h.id} is finished ({h._finish!r}) "
                           f"but still scheduled at {where}")
        if srv.pending != len(srv._queue):
            bad.append(f"pending={srv.pending} != queue len {len(srv._queue)}")

        if srv.paged:
            bad += self._audit_pages(live_rows, task_rows)

        self.audits += 1
        if bad:
            self.violations.extend(bad)
        return bad

    def _audit_pages(self, live_rows: set, task_rows: set) -> list[str]:
        srv = self.server
        bad: list[str] = []
        pt = srv._pt_host
        B = pt.shape[0]

        # 1. pool partition, per shard pool
        for pool in self._shard_pools():
            free = pool._free
            if len(set(free)) != len(free):
                bad.append(f"pool@{pool.offset}: duplicate free pages")
            overlap = set(free) & pool._live
            if overlap:
                bad.append(f"pool@{pool.offset}: pages both free and live: "
                           f"{sorted(overlap)[:8]}")
            if pool.free_pages + pool.live_pages != pool.n_pages:
                bad.append(
                    f"pool@{pool.offset}: free({pool.free_pages}) + "
                    f"live({pool.live_pages}) != n_pages({pool.n_pages})")
            if set(pool._ref) != pool._live:
                bad.append(f"pool@{pool.offset}: refcount keys != live set")
            for p, c in pool._ref.items():
                if c < 1:
                    bad.append(f"pool@{pool.offset}: live page {p} has "
                               f"refcount {c}")

        # 2./3. refcount balance, aliasing, shard affinity
        expected: dict[int, int] = {}
        scheduled = live_rows | task_rows
        for row in range(B):
            pages = pt[row][pt[row] >= 0]
            if row not in scheduled:
                if len(pages):
                    bad.append(f"unscheduled row {row} still holds pages "
                               f"{pages.tolist()}")
                continue
            if len(set(pages.tolist())) != len(pages):
                bad.append(f"row {row} references a page twice: "
                           f"{pages.tolist()}")
            own = srv._shard_pool(row)
            for p in pages.tolist():
                expected[p] = expected.get(p, 0) + 1
                if not own.owns(p):
                    bad.append(f"row {row} (shard {srv._row_shard(row)}) "
                               f"references foreign page {p}")
        for ix in (getattr(srv, "_indexes", None) or []):
            for p in ix.indexed_pages():
                expected[p] = expected.get(p, 0) + 1
        actual = {}
        for pool in self._shard_pools():
            actual.update(pool._ref)
        for p in sorted(set(expected) | set(actual)):
            e, a = expected.get(p, 0), actual.get(p, 0)
            if e != a:
                bad.append(f"page {p}: pool refcount {a} but "
                           f"{e} referencing owners (rows + index nodes)")

        # 4. device page tables mirror the host (every layer)
        caches = srv.state.get("kv") if isinstance(srv.state, dict) else None
        tabs = []
        if isinstance(caches, (tuple, list)):
            tabs = [(_np_rows(c.page_tab), f"layer{i}")
                    for i, c in enumerate(caches)]
        elif caches is not None:
            stacked = _np_rows(caches.page_tab)
            tabs = [(stacked[l], f"layer{l}") for l in range(stacked.shape[0])]
        for dev, name in tabs:
            for row in range(B):
                want = pt[row] if row in live_rows else np.full_like(pt[row], -1)
                if not np.array_equal(dev[row], want):
                    state = ("live" if row in live_rows else
                             "prefilling" if row in task_rows else "free")
                    bad.append(
                        f"{name} device page table row {row} ({state}) = "
                        f"{dev[row].tolist()} but host expects {want.tolist()}")
            if len(tabs) > 1 and not np.array_equal(dev, tabs[0][0]):
                bad.append(f"{name} page table differs from layer0")
        return bad

    def check(self) -> None:
        bad = self.audit()
        if bad:
            raise InvariantViolation(
                f"invariant audit #{self.audits} found {len(bad)} "
                "violation(s):\n  " + "\n  ".join(bad))

    def report(self) -> dict:
        return {"audits": self.audits,
                "violations": list(self.violations),
                "clean": not self.violations}
