"""Public jit'd kernel wrappers.

``impl`` selects the execution path:
  * ``"pallas"``    — the Pallas kernels (interpret mode on CPU; compiled
                      Mosaic on real TPU).
  * ``"xla"``       — the pure-jnp oracle (used by the distributed serve step
                      and the multi-pod dry-run, where portability matters).
  * ``"auto"``      — pallas on TPU backends, xla elsewhere.

The wrappers also normalize layout quirks (odd head_dims are padded to the
next multiple of 128 lanes before entering the MXU-shaped kernel).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_kv_attn import fused_decode_attention_pallas

Array = jax.Array


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return _default_impl()
    if impl not in ("pallas", "xla"):
        raise ValueError(f"impl must be auto|pallas|xla, got {impl}")
    return impl


@functools.partial(
    jax.jit,
    static_argnames=("bits_k", "bits_v", "block_size", "scale", "impl", "interpret"),
)
def fused_decode_attention(
    q: Array,
    k_store: Array, k_min: Array, k_step: Array,
    v_store: Array, v_min: Array, v_step: Array,
    k_buf: Array, v_buf: Array,
    nb_valid: Array, buf_len: Array,
    *,
    bits_k: int, bits_v: int, block_size: int,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = True,
):
    """Full decode attention over (packed store ∥ raw buffer) -> [B, Hq, D].

    The packed part runs in the fused kernel (or its oracle); the small raw
    buffer part runs in XLA and is merged with a two-part softmax combine.
    """
    impl = resolve_impl(impl)
    D = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kw = dict(bits_k=bits_k, bits_v=bits_v, block_size=block_size, scale=scale)
    if impl == "pallas":
        acc, m, l = fused_decode_attention_pallas(
            q, k_store, k_min, k_step, v_store, v_min, v_step, nb_valid,
            interpret=interpret, **kw)
    else:
        acc, m, l = ref.fused_decode_attention_ref(
            q, k_store, k_min, k_step, v_store, v_min, v_step, nb_valid, **kw)
    return ref.combine_with_buffer_ref(acc, m, l, q, k_buf, v_buf, buf_len, scale=scale)


def cache_decode_attention(cache, q: Array, impl: str = "auto", interpret: bool = True):
    """Convenience: fused decode attention straight from a LayerKVCache.

    Only layouts that advertise ``supports_fused`` (uniform no-straddle
    words) can enter the Pallas kernel; others must use the generic
    ``repro.core.cache.attend`` fetch path.
    """
    spec = cache.spec
    if not spec.impl.supports_fused:
        raise ValueError(
            f"fused kernel requires a fused-capable layout "
            f"(got {spec.layout!r}; see layouts.CacheLayout.supports_fused)")
    return fused_decode_attention(
        q,
        cache.k_store, cache.k_min, cache.k_step,
        cache.v_store, cache.v_min, cache.v_step,
        cache.k_buf, cache.v_buf,
        jnp.minimum(cache.n_flushed, spec.n_blocks), cache.buf_len,
        bits_k=spec.bits_k, bits_v=spec.bits_v, block_size=spec.block_size,
        impl=impl, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("rel_scale", "bits", "token_wise", "impl", "interpret"))
def quant_pack(
    x: Array, *, rel_scale: float, bits: int, token_wise: bool,
    impl: str = "auto", interpret: bool = True,
):
    """Store-stage compression of [NBLK, T, D] raw blocks."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        from repro.kernels.pack_encode import quant_pack_pallas

        return quant_pack_pallas(x, rel_scale, bits, token_wise, interpret=interpret)
    return ref.quant_pack_ref(x, rel_scale, bits, token_wise)
