"""Public jit'd kernel wrappers + the attention-backend registry.

Two orthogonal selection axes (DESIGN.md §9):

* **backend** — which decode-attention algorithm serves a cache:
    * ``"fused"`` — the Pallas in-situ-decompression kernel
      (``repro.kernels.fused_kv_attn``), parameterized by the layout's
      ``tile_decode`` hook; requires ``CacheLayout.supports_fused``.
    * ``"xla"``   — the blockwise lazily-dequantized flash-decode scan
      (``repro.core.cache.attend_blockwise``); works for every layout and is
      the portable floor.
    * ``"auto"``  — fused on real TPU for fused-capable layouts, xla
      elsewhere.
  New backends register with ``@register_backend("name")`` (same pattern as
  the cache-layout registry).  The ``REPRO_ATTN_BACKEND`` env var overrides
  the selection at trace time — the CI matrix uses it to keep both paths
  green on CPU.

* **impl** — within the fused backend, which code path executes:
  ``"pallas"`` (interpret mode off-TPU, compiled Mosaic on real TPU) or
  ``"xla"`` (the vmapped pure-jnp oracle in ``repro.kernels.ref``);
  ``"auto"`` picks pallas on TPU and the oracle elsewhere.

The dispatch entry is ``decode_attention`` — what every
``CacheLayout.attend_block`` routes through, making it the single point the
model decode path, the serving scheduler, and the api facade all share.
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_kv_attn import fused_cache_attention_pallas
from repro.kernels.runtime import resolve_impl, resolve_interpret  # noqa: F401  (re-export)
from repro.obs.profiling import annotate

Array = jax.Array


# ---------------------------------------------------------------------------
# Attention-backend registry
# ---------------------------------------------------------------------------


_BACKENDS: dict[str, object] = {}

ENV_BACKEND = "REPRO_ATTN_BACKEND"


def register_backend(name: str):
    """Function decorator: register ``fn(cache, q, scale) -> [B, Hq, D]`` as
    a decode-attention backend under ``name``."""

    def deco(fn):
        _BACKENDS[name] = fn
        return fn

    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def resolve_backend(backend: str | None, layout) -> str:
    """Collapse (requested backend, env override, layout capability, host
    platform) to a registered backend name.

    ``REPRO_ATTN_BACKEND`` (read at trace time) replaces an ``auto``
    selection — explicit requests win, so the CI matrix steers every
    default-configured path without defeating tests that pin a backend.
    ``auto`` resolves to fused on real TPU for fused-capable layouts and to
    the blockwise scan elsewhere; a fused request against a layout without
    ``supports_fused`` (every built-in layout is fused-capable now that
    huffman decodes in-kernel, but custom layouts need not be) falls back
    to the blockwise scan — the portable floor every layout can serve from.
    """
    from repro.kernels.runtime import on_tpu

    name = backend or "auto"
    if name == "auto":
        name = os.environ.get(ENV_BACKEND) or "auto"
    if name == "auto":
        name = "fused" if (on_tpu() and layout.supports_fused) else "xla"
    if name == "fused" and not layout.supports_fused:
        name = "xla"
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown attention backend {name!r}; available: {available_backends()}")
    return name


def decode_attention(cache, q: Array, scale: float | None = None,
                     backend: str | None = None) -> Array:
    """Decode attention over (store ∥ buffer) — the registry dispatch point.

    ``backend=None`` defers to ``cache.spec.attn_backend`` (itself
    ``"auto"`` unless a CompressionPolicy/ModelConfig pinned it).
    """
    name = resolve_backend(backend if backend is not None else cache.spec.attn_backend,
                           cache.spec.impl)
    return _BACKENDS[name](cache, q, scale)


@register_backend("xla")
def _xla_backend(cache, q: Array, scale: float | None = None) -> Array:
    from repro.core import cache as kvcache  # late: core imports this module

    return kvcache.attend_blockwise(cache, q, scale)


@register_backend("fused")
def _fused_backend(cache, q: Array, scale: float | None = None) -> Array:
    return cache_decode_attention(cache, q, scale=scale)


# ---------------------------------------------------------------------------
# Fused-kernel wrappers
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("tile", "block_size", "scale", "impl", "interpret"),
)
def fused_cache_attention(
    q: Array,
    k_store: Array, k_min: Array, k_step: Array,
    v_store: Array, v_min: Array, v_step: Array,
    k_buf: Array, v_buf: Array,
    nb_valid: Array, buf_len: Array,
    page_tab: Array | None = None,
    *,
    tile,  # layouts.FusedTileSpec (memoized — hashable static arg)
    block_size: int,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool | str = "auto",
) -> Array:
    """Full decode attention over (store ∥ buffer) -> [B, Hq, D].

    ``impl="pallas"`` runs the single fused kernel (buffer tail folded into
    its softmax combine); ``impl="xla"`` runs the vmapped oracle.  A
    ``page_tab`` (i32 [B, NB]) marks the stores as a shared paged arena
    (DESIGN.md §10): both impls gather K/V tiles through the table —
    the kernel in its scalar-prefetch index maps, the oracle by an explicit
    per-row gather.
    """
    impl = resolve_impl(impl)
    D = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kw = dict(tile=tile, block_size=block_size, scale=scale)
    # Profiling attribution (DESIGN.md §14): device profiles tag this whole
    # fused in-situ-decompression attention as one named compression stage.
    with annotate("fused_attention"):
        if impl == "pallas":
            out = fused_cache_attention_pallas(
                q, k_store, k_min, k_step, v_store, v_min, v_step,
                k_buf, v_buf, nb_valid, buf_len, page_tab,
                interpret=interpret, **kw)
        else:
            out = ref.fused_cache_attention_ref(
                q, k_store, k_min, k_step, v_store, v_min, v_step,
                k_buf, v_buf, nb_valid, buf_len, page_tab, **kw)
    return out.astype(q.dtype)


def cache_decode_attention(cache, q: Array, scale: float | None = None,
                           impl: str = "auto", interpret: bool | str = "auto"):
    """Fused decode attention straight from a LayerKVCache (the ``"fused"``
    backend body).

    Only layouts whose ``tile_decode`` returns a plan (``supports_fused``)
    can enter the kernel; the backend resolver routes everything else to the
    blockwise ``repro.core.cache.attend_blockwise`` path first.
    """
    spec = cache.spec
    tile = spec.impl.tile_decode(spec, cache.head_dim)
    if tile is None:
        raise ValueError(
            f"fused kernel requires a fused-capable layout "
            f"(got {spec.layout!r}; see layouts.CacheLayout.tile_decode)")
    return fused_cache_attention(
        q,
        cache.k_store, cache.k_min, cache.k_step,
        cache.v_store, cache.v_min, cache.v_step,
        cache.k_buf, cache.v_buf,
        jnp.minimum(cache.n_flushed, spec.n_blocks), cache.buf_len,
        cache.page_tab if spec.paged else None,
        tile=tile, block_size=spec.block_size, scale=scale,
        impl=impl, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Store-stage kernel wrapper
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("rel_scale", "bits", "token_wise", "impl", "interpret"))
def quant_pack(
    x: Array, *, rel_scale: float, bits: int, token_wise: bool,
    impl: str = "auto", interpret: bool | str = "auto",
):
    """Store-stage compression of [NBLK, T, D] raw blocks."""
    impl = resolve_impl(impl)
    with annotate("pack_encode"):
        if impl == "pallas":
            from repro.kernels.pack_encode import quant_pack_pallas

            return quant_pack_pallas(x, rel_scale, bits, token_wise,
                                     interpret=interpret)
        return ref.quant_pack_ref(x, rel_scale, bits, token_wise)
