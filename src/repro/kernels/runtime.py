"""Execution-environment resolution shared by every kernel wrapper.

Two independent axes select how a kernel runs:

* ``impl``      — which code path: ``"pallas"`` (the Mosaic kernel) or
                  ``"xla"`` (the pure-jnp oracle).  ``"auto"`` picks pallas on
                  real TPU and xla elsewhere.
* ``interpret`` — whether a Pallas call runs under the interpreter.
                  ``"auto"`` resolves to ``False`` on real TPU (compiled
                  Mosaic) and ``True`` everywhere else, so TPU runs never
                  silently execute interpret-mode kernels and CPU tests never
                  try to compile Mosaic.

Both resolvers read ``jax.default_backend()`` at trace time.
"""

from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if on_tpu() else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"impl must be auto|pallas|xla, got {impl}")
    return impl


def resolve_interpret(interpret: bool | str) -> bool:
    if interpret == "auto":
        return not on_tpu()
    if not isinstance(interpret, bool):
        raise ValueError(f'interpret must be "auto" or a bool, got {interpret!r}')
    return interpret
