"""Pallas kernel: the paper's branch-divergence-free Huffman decode (§3.3.1),
optionally fused with the K-score dot product ("single kernel").

This is the *faithful* port: one VPU lane plays one CUDA thread, walking the
array-based Huffman tree one bit per iteration with the paper's branchless
updates —

    idx    = children[idx, bit]
    out[w] = symbols[idx]              (position advances only at leaves)
    w     += is_symbol[idx]
    idx   *= 1 - is_symbol[idx]        (≡ idx &= ~(-is_symbol) reset-to-root)

Every lane executes the identical instruction sequence; there is no data-
dependent control flow anywhere in the loop, exactly as in the paper.

DESIGN.md §2 records the hardware caveat: the per-lane gathers
(``children[idx, bit]``, the masked output scatter, and ``q[w]`` in the fused
variant) vectorize in interpret mode but are VPU-hostile on real TPU hardware;
the production bandwidth path is ``fused_kv_attn`` over the no-straddle
layout.  This kernel exists to validate the algorithm end-to-end and to
measure the faithful single-kernel-vs-multi-kernel comparison (paper Fig. 9).

Layout: one grid step decodes one 2D block — ``S`` streams (rows of
``head_dim`` symbols) packed tightly in stream order inside the block's
payload slot, with per-stream bit counts (the paper's u16 thread metadata).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _walk(payload, nbits, children, is_symbol, symbols, n_per_stream, max_bits, S):
    """The branchless lockstep walk shared by both kernel variants.

    Returns decoded codes [S, n_per_stream] float32.
    """
    nbits_i = nbits.astype(jnp.int32)
    starts = jnp.cumsum(nbits_i) - nbits_i  # deterministic per-stream offsets
    lane = jax.lax.broadcasted_iota(jnp.int32, (S, n_per_stream), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (S, n_per_stream), 1)

    def body(p, carry):
        idx, w, out = carry
        gpos = starts + p  # [S]
        word = payload[gpos >> 5]  # per-lane gather (interpret-mode)
        bit = ((word >> (gpos & 31).astype(jnp.uint32)) & 1).astype(jnp.int32)
        idx = children[idx, bit]
        active = (p < nbits_i).astype(jnp.int32)
        isym = is_symbol[idx] * active
        sym = symbols[idx].astype(jnp.float32)
        # Masked broadcast-write: lane s writes column w[s] iff at a leaf.
        hit = (col == w[:, None]) & (isym[:, None] == 1)
        out = jnp.where(hit, sym[:, None], out)
        w = w + isym
        idx = idx * (1 - isym)  # branchless reset-to-root
        return idx, w, out

    idx0 = jnp.zeros((S,), jnp.int32)
    w0 = jnp.zeros((S,), jnp.int32)
    out0 = jnp.zeros((S, n_per_stream), jnp.float32)
    _, _, out = jax.lax.fori_loop(0, max_bits, body, (idx0, w0, out0))
    del lane
    return out


def _decode_kernel(payload_ref, nbits_ref, ch_ref, isym_ref, sym_ref, out_ref,
                   *, n_per_stream, max_bits, S):
    codes = _walk(
        payload_ref[0], nbits_ref[0], ch_ref[...], isym_ref[...], sym_ref[...],
        n_per_stream, max_bits, S,
    )
    out_ref[0] = codes.astype(jnp.uint8)


def _fused_scores_kernel(payload_ref, nbits_ref, ch_ref, isym_ref, sym_ref,
                         kmn_ref, kst_ref, q_ref, out_ref,
                         *, n_per_stream, max_bits, S, scale):
    codes = _walk(
        payload_ref[0], nbits_ref[0], ch_ref[...], isym_ref[...], sym_ref[...],
        n_per_stream, max_bits, S,
    )
    # Cache-resident consumption: dequantize + dot in VMEM, emit scores only.
    kd = kmn_ref[0][None, :] + codes * kst_ref[0][None, :]  # [S, D]
    q = q_ref[...].astype(jnp.float32)  # [D]
    out_ref[0] = (kd @ q) * scale


def huffman_decode_pallas(
    payload: Array,   # u32 [NBLK, Wslot] — per-block payload slots
    nbits: Array,     # u16 [NBLK, S]
    children: Array,  # i32 [MAXN, 2]
    is_symbol: Array, # i32 [MAXN]
    symbols: Array,   # i32 [MAXN]
    n_per_stream: int,
    max_bits: int,
    interpret: bool | str = "auto",
) -> Array:
    """Decode every block -> uint8 [NBLK, S, n_per_stream]."""
    NBLK, Wslot = payload.shape
    S = nbits.shape[1]
    MAXN = children.shape[0]
    kernel = functools.partial(
        _decode_kernel, n_per_stream=n_per_stream, max_bits=max_bits, S=S)
    return pl.pallas_call(
        kernel,
        grid=(NBLK,),
        in_specs=[
            pl.BlockSpec((1, Wslot), lambda n: (n, 0)),
            pl.BlockSpec((1, S), lambda n: (n, 0)),
            pl.BlockSpec((MAXN, 2), lambda n: (0, 0)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((1, S, n_per_stream), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((NBLK, S, n_per_stream), jnp.uint8),
        interpret=resolve_interpret(interpret),
    )(payload, nbits, children, is_symbol, symbols)


def huffman_attn_scores_pallas(
    payload: Array, nbits: Array,
    children: Array, is_symbol: Array, symbols: Array,
    k_min: Array,   # [NBLK, D]
    k_step: Array,  # [NBLK, D]
    q: Array,       # [D]
    max_bits: int,
    scale: float = 1.0,
    interpret: bool | str = "auto",
) -> Array:
    """Fused single kernel: Huffman decode + dequant + K·q scores [NBLK, S]."""
    NBLK, Wslot = payload.shape
    S = nbits.shape[1]
    D = q.shape[0]
    MAXN = children.shape[0]
    kernel = functools.partial(
        _fused_scores_kernel, n_per_stream=D, max_bits=max_bits, S=S, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(NBLK,),
        in_specs=[
            pl.BlockSpec((1, Wslot), lambda n: (n, 0)),
            pl.BlockSpec((1, S), lambda n: (n, 0)),
            pl.BlockSpec((MAXN, 2), lambda n: (0, 0)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
            pl.BlockSpec((1, D), lambda n: (n, 0)),
            pl.BlockSpec((1, D), lambda n: (n, 0)),
            pl.BlockSpec((D,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((1, S), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((NBLK, S), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(payload, nbits, children, is_symbol, symbols, k_min, k_step, q)
