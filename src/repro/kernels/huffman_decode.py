"""Pallas kernel: the paper's branch-divergence-free Huffman decode (§3.3.1),
optionally fused with the K-score dot product ("single kernel").

This is the *faithful* port: one VPU lane plays one CUDA thread, walking the
array-based Huffman tree one bit per iteration with the paper's branchless
updates —

    idx    = children[idx, bit]
    out[w] = symbols[idx]              (position advances only at leaves)
    w     += is_symbol[idx]
    idx   *= 1 - is_symbol[idx]        (≡ idx &= ~(-is_symbol) reset-to-root)

Every lane executes the identical instruction sequence; there is no data-
dependent control flow anywhere in the loop, exactly as in the paper.

DESIGN.md §2 records the hardware caveat: the per-lane gathers
(``children[idx, bit]``, the masked output scatter, and ``q[w]`` in the fused
variant) vectorize in interpret mode but are VPU-hostile on real TPU hardware;
the production bandwidth path is ``fused_kv_attn`` over the no-straddle
layout.  This kernel exists to validate the algorithm end-to-end and to
measure the faithful single-kernel-vs-multi-kernel comparison (paper Fig. 9).

Layout: one grid step decodes one 2D block — ``S`` streams (rows of
``head_dim`` symbols) packed tightly in stream order inside the block's
payload slot, with per-stream bit counts (the paper's u16 thread metadata).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _walk(payload, nbits, children, is_symbol, symbols, n_per_stream, max_bits, S):
    """The branchless lockstep walk shared by both kernel variants.

    The walk body lives in ``repro.core.huffman.walk_decode_jax`` — the
    SAME kernel-safe function the jnp oracle runs, so kernel and oracle
    cannot drift.  Returns decoded codes [S, n_per_stream] float32.
    """
    from repro.core import huffman  # kernels import core; cycle-free

    del S
    return huffman.walk_decode_jax(
        payload, nbits, children, is_symbol, symbols, n_per_stream, max_bits)


def _decode_kernel(payload_ref, nbits_ref, ch_ref, isym_ref, sym_ref, out_ref,
                   *, n_per_stream, max_bits, S):
    codes = _walk(
        payload_ref[0], nbits_ref[0], ch_ref[...], isym_ref[...], sym_ref[...],
        n_per_stream, max_bits, S,
    )
    out_ref[0] = codes.astype(jnp.uint8)


def _fused_scores_kernel(payload_ref, nbits_ref, ch_ref, isym_ref, sym_ref,
                         kmn_ref, kst_ref, q_ref, out_ref,
                         *, n_per_stream, max_bits, S, scale):
    codes = _walk(
        payload_ref[0], nbits_ref[0], ch_ref[...], isym_ref[...], sym_ref[...],
        n_per_stream, max_bits, S,
    )
    # Cache-resident consumption: dequantize + dot in VMEM, emit scores only.
    kd = kmn_ref[0][None, :] + codes * kst_ref[0][None, :]  # [S, D]
    q = q_ref[...].astype(jnp.float32)  # [D]
    out_ref[0] = (kd @ q) * scale


def huffman_decode_pallas(
    payload: Array,   # u32 [NBLK, Wslot] — per-block payload slots
    nbits: Array,     # u16 [NBLK, S]
    children: Array,  # i32 [MAXN, 2]
    is_symbol: Array, # i32 [MAXN]
    symbols: Array,   # i32 [MAXN]
    n_per_stream: int,
    max_bits: int,
    interpret: bool | str = "auto",
) -> Array:
    """Decode every block -> uint8 [NBLK, S, n_per_stream]."""
    NBLK, Wslot = payload.shape
    S = nbits.shape[1]
    MAXN = children.shape[0]
    kernel = functools.partial(
        _decode_kernel, n_per_stream=n_per_stream, max_bits=max_bits, S=S)
    return pl.pallas_call(
        kernel,
        grid=(NBLK,),
        in_specs=[
            pl.BlockSpec((1, Wslot), lambda n: (n, 0)),
            pl.BlockSpec((1, S), lambda n: (n, 0)),
            pl.BlockSpec((MAXN, 2), lambda n: (0, 0)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((1, S, n_per_stream), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((NBLK, S, n_per_stream), jnp.uint8),
        interpret=resolve_interpret(interpret),
    )(payload, nbits, children, is_symbol, symbols)


def huffman_attn_scores_pallas(
    payload: Array, nbits: Array,
    children: Array, is_symbol: Array, symbols: Array,
    k_min: Array,   # [NBLK, D]
    k_step: Array,  # [NBLK, D]
    q: Array,       # [D]
    max_bits: int,
    scale: float = 1.0,
    interpret: bool | str = "auto",
) -> Array:
    """Fused single kernel: Huffman decode + dequant + K·q scores [NBLK, S]."""
    NBLK, Wslot = payload.shape
    S = nbits.shape[1]
    D = q.shape[0]
    MAXN = children.shape[0]
    kernel = functools.partial(
        _fused_scores_kernel, n_per_stream=D, max_bits=max_bits, S=S, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(NBLK,),
        in_specs=[
            pl.BlockSpec((1, Wslot), lambda n: (n, 0)),
            pl.BlockSpec((1, S), lambda n: (n, 0)),
            pl.BlockSpec((MAXN, 2), lambda n: (0, 0)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
            pl.BlockSpec((MAXN,), lambda n: (0,)),
            pl.BlockSpec((1, D), lambda n: (n, 0)),
            pl.BlockSpec((1, D), lambda n: (n, 0)),
            pl.BlockSpec((D,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((1, S), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((NBLK, S), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(payload, nbits, children, is_symbol, symbols, k_min, k_step, q)
