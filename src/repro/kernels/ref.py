"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function mirrors one kernel's semantics exactly, built only from jnp ops
already validated against numpy in ``repro.core``.  Kernel tests sweep shapes
and dtypes and ``assert_allclose`` kernel-vs-oracle.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import bitpack

Array = jax.Array

NEG_INIT = -1e30  # finite "-inf" so flash combines never produce NaN


def dequant_k(codes: Array, k_min: Array, k_step: Array) -> Array:
    """codes [..., T, D], k_min/k_step [..., D] (BlockQuant units)."""
    return k_min[..., None, :].astype(jnp.float32) + codes.astype(jnp.float32) * k_step[..., None, :].astype(jnp.float32)


def dequant_v(codes: Array, v_min: Array, v_step: Array) -> Array:
    """codes [..., T, D], v_min/v_step [..., T] (TokenQuant units)."""
    return v_min[..., None].astype(jnp.float32) + codes.astype(jnp.float32) * v_step[..., None].astype(jnp.float32)


def fused_cache_attention_ref(
    q: Array,          # [B, Hq, D]
    k_store: Array,    # [B, Hkv, NB, *tile.k_tile]  (paged: [1, Hkv, P, ...])
    k_min: Array,      # [B, Hkv, NB, D] (ignored when not tile.has_scales)
    k_step: Array,
    v_store: Array,    # [B, Hkv, NB, *tile.v_tile]
    v_min: Array,      # [B, Hkv, NB, T]
    v_step: Array,
    k_buf: Array, v_buf: Array,  # [B, Hkv, T, D]
    nb_valid: Array,   # i32 [B] per-row valid block counts (scalar broadcasts)
    buf_len: Array,    # i32 [B] per-row buffer lengths (scalar broadcasts)
    page_tab: Array | None = None,  # i32 [B, NB] paged: slot -> arena page
    *,
    tile,              # layouts.FusedTileSpec — same decode the kernel runs
    block_size: int,
    scale: float | None = None,
) -> Array:
    """Oracle for the fused in-situ-decompression attention kernel.

    vmaps the layout's per-tile decode over (B, Hkv, NB) — deliberately
    materializing the dequantized store, because that is what makes it an
    oracle rather than a second implementation of the lazily-decoded paths.
    With ``page_tab`` the stores are a shared paged arena (DESIGN.md §10):
    each row's tiles are gathered through its page-table entries first —
    the same indirection the kernel performs in its index maps — and
    unassigned slots clamp to page 0 under the ``nb_valid`` mask.  Slots
    whose table entry is unassigned (< 0) are additionally masked
    regardless of ``nb_valid`` — the shard-local table semantics of
    DESIGN.md §12, where a shard sees ``-1`` for any block it does not
    host and must contribute nothing for it.
    Returns the normalized output [B, Hq, D] f32 (buffer tail included).
    """
    B, Hq, D = q.shape
    page_ok = None
    if page_tab is not None:
        P = k_store.shape[2]
        idx = jnp.clip(page_tab, 0, P - 1)  # [B, NB]
        page_ok = page_tab >= 0            # [B, NB]
        gather = lambda a: jnp.moveaxis(jnp.take(a[0], idx, axis=1), 1, 0)
        k_store, v_store = gather(k_store), gather(v_store)
        if tile.has_scales:
            k_min, k_step = gather(k_min), gather(k_step)
            v_min, v_step = gather(v_min), gather(v_step)
    Hkv, NB = k_store.shape[1], k_store.shape[2]
    G, T = Hq // Hkv, block_size
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    nbv = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(nb_valid, jnp.int32)), (B,))

    # Per-layer aux operands (block-invariant — e.g. huffman's decode LUTs)
    # are closed over un-vmapped, mirroring the kernel's constant index maps.
    aux = tuple(jnp.asarray(a) for a in tile.aux)

    def dec3(fn, store, mn, st):
        if tile.has_scales:
            f = jax.vmap(jax.vmap(jax.vmap(lambda t, m, s: fn(t, m, s, *aux))))
            return f(store, mn, st)
        f = jax.vmap(jax.vmap(jax.vmap(lambda t: fn(t, None, None, *aux))))
        return f(store)

    kd = dec3(tile.decode_k, k_store, k_min, k_step)  # [B,Hkv,NB,T,D] f32
    vd = dec3(tile.decode_v, v_store, v_min, v_step)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhntd->bhgnt", qg, kd) * scale
    ok_b = jnp.arange(NB)[None, :] < nbv[:, None]  # [B, NB]
    if page_ok is not None:
        ok_b = ok_b & page_ok
    ok = ok_b[:, None, None, :, None]
    s = jnp.where(ok, s, NEG_INIT)
    s2 = s.reshape(B, Hkv, G, NB * T)
    m = jnp.max(s2, axis=-1)
    m = jnp.maximum(m, NEG_INIT)
    p = jnp.exp(s2 - m[..., None]) * jnp.repeat(ok[..., 0], T, -1)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgnt,bhntd->bhgd", p.reshape(B, Hkv, G, NB, T), vd)
    return combine_with_buffer_ref(
        acc.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq),
        q, k_buf, v_buf, buf_len, scale=scale)


def combine_with_buffer_ref(
    acc: Array, m: Array, l: Array,  # from the main (packed) part
    q: Array,                        # [B, Hq, D]
    k_buf: Array, v_buf: Array,      # [B, Hkv, T, D]
    buf_len: Array,                  # i32 [B] per-row (scalar broadcasts)
    scale: float | None = None,
):
    """Two-part softmax combine: packed-store partials + raw tail buffer."""
    B, Hq, D = q.shape
    Hkv, T = k_buf.shape[1], k_buf.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bl = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(buf_len, jnp.int32)), (B,))
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg, k_buf.astype(jnp.float32)) * scale
    ok = (jnp.arange(T)[None, :] < bl[:, None])[:, None, None, :]
    s = jnp.where(ok, s, NEG_INIT)
    mb = jnp.maximum(jnp.max(s, axis=-1), NEG_INIT)
    pb = jnp.exp(s - mb[..., None]) * ok
    lb = jnp.sum(pb, axis=-1)
    accb = jnp.einsum("bhgt,bhtd->bhgd", pb, v_buf.astype(jnp.float32))
    mb, lb, accb = mb.reshape(B, Hq), lb.reshape(B, Hq), accb.reshape(B, Hq, D)

    M = jnp.maximum(m, mb)
    a1 = jnp.exp(m - M)
    a2 = jnp.exp(mb - M)
    denom = l * a1 + lb * a2
    out = (acc * a1[..., None] + accb * a2[..., None]) / jnp.maximum(denom, 1e-30)[..., None]
    return out


def quant_pack_ref(x: Array, rel_scale: float, bits: int, token_wise: bool):
    """Oracle for the Store-stage kernel: quantize + no-straddle pack.

    x: [NBLK, T, D].  token_wise=False -> K BlockQuant (units: block×channel);
    True -> V TokenQuant (units: token).
    Returns (words u32 [NBLK, W], mn, step).
    """
    xf = x.astype(jnp.float32)
    axes = (-1,) if token_wise else (-2,)
    mn = jnp.min(xf, axis=axes, keepdims=True)
    mx = jnp.max(xf, axis=axes, keepdims=True)
    step = rel_scale * (mx - mn)
    safe = jnp.where(step > 0, step, 1.0)
    codes = jnp.clip(jnp.round((xf - mn) / safe), 0, 2**bits - 1).astype(jnp.uint8)
    NBLK, T, D = x.shape
    words = bitpack.pack_nostraddle(codes.reshape(NBLK, T * D), bits)
    return words, jnp.squeeze(mn, axes), jnp.squeeze(step, axes)


def huffman_decode_ref(payload, nbits, children, is_symbol, symbols, n_per_stream, max_stream_bits):
    """Oracle for the branchless-walk kernel: defer to the validated core impl."""
    from repro.core import huffman

    return huffman.decode_block_jax(
        payload, nbits, children, is_symbol, symbols, n_per_stream, max_stream_bits
    )


def huffman_attn_scores_ref(
    payload, nbits, children, is_symbol, symbols,
    k_min, k_step, q, max_stream_bits,
):
    """Oracle for the fused Huffman-decode + dot-product kernel.

    One stream per cached token (a [head_dim] K row).  Returns scores [S]:
    score_s = dequant(decode(stream_s)) · q.
    """
    D = q.shape[-1]
    codes = huffman_decode_ref(payload, nbits, children, is_symbol, symbols, D, max_stream_bits)
    kd = k_min[None, :].astype(jnp.float32) + codes.astype(jnp.float32) * k_step[None, :].astype(jnp.float32)
    return kd @ q.astype(jnp.float32)
