"""Pallas TPU kernel: fused in-situ decompression + flash-decode attention.

The TPU realization of the paper's cache-resident decompression (§3.3.2):
compressed store tiles stream HBM→VMEM once per block; decoding (layout-owned
— see below), dequantization, and the attention matvec all happen inside the
kernel on VMEM/VREG data.  The decompressed K/V tiles are never written back
to HBM — exactly the paper's "decompressed data consumed in situ", with VMEM
playing the role of GPU shared memory and the MXU taking the dot products.

The per-tile decode is NOT hardcoded to one layout: the kernel is
parameterized by a ``repro.core.layouts.FusedTileSpec`` — the layout-owned
``tile_decode`` hook (DESIGN.md §9).  ``packed``/``kivi`` share the
no-straddle shift/mask unpack; ``raw`` plugs in a passthrough decoder; and
``huffman`` decodes its ragged-payload slots via the tile spec's per-layer
``aux`` operands — block-invariant arrays (the canonical codebooks' chunked
direct-lookup LUTs) the kernel stages into VMEM with constant index maps
and appends to every decode call, while the per-stream u16 bit counts
arrive as part of the fixed worst-case-padded slot tile itself.  So the
kernel is the uniform decode path rather than a packed-only special case.

Grid: ``(B, Hkv, NB + 1)``.  TPU grids execute sequentially with the last
axis innermost, so VMEM scratch carries the flash-decoding running state
``(m, l, acc)`` across the block axis for each (batch, kv-head) pair.  The
extra final step folds the raw append buffer (the exact residual window) into
the same running softmax — masked per row by ``buf_len`` — and emits the
normalized output, so no separate XLA combine pass runs after the kernel.

Per-row ``nb_valid``/``buf_len`` arrive as scalar-prefetch args (indexed by
the batch grid axis before the body runs): every row of a continuous batch
attends at its own position, the contract the serving scheduler relies on.
Paged caches (DESIGN.md §10) add the per-row page table as a third
scalar-prefetch operand: the store BlockSpec index maps resolve logical
block ``n`` of row ``b`` to its physical arena page before the tile streams
HBM→VMEM, so the kernel body decodes pooled storage completely unchanged.

Block shapes keep the MXU happy when ``D`` and ``block_size`` are multiples
of 128/8; odd head_dims (80, 112, 160 in the assigned archs) run via the
interpreter off-TPU and rely on Mosaic relayout on real hardware.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INIT
from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _kernel(
    nb_ref,        # scalar prefetch: i32 [B] per-row valid block counts
    bl_ref,        # scalar prefetch: i32 [B] per-row buffer lengths
    *refs,
    decode_k,
    decode_v,
    has_scales: bool,
    n_aux: int,
    block_size: int,
    head_dim: int,
    scale: float,
    nb_total: int,
    paged: bool = False,
):
    pt_ref = None
    if paged:
        # The page table rides as a third scalar-prefetch operand.  The
        # BlockSpec index maps consume it to resolve logical block n to its
        # arena page before the tile streams HBM→VMEM; the body reads it
        # once more for the validity guard below (shard-local tables mark
        # blocks this arena does not host with -1 — DESIGN.md §12 — and
        # those steps must contribute nothing, not decode a clamped page).
        pt_ref = refs[0]
        refs = refs[1:]
    # Per-layer aux operands (block-invariant, e.g. huffman's decode LUTs)
    # sit between the buffers and the output; their VMEM-resident values
    # are appended to every decode call — read inside the decode-step guard
    # only, so skipped steps and the buffer-combine step never load them.
    if n_aux:
        aux_refs = refs[-(4 + n_aux):-4]
        refs = refs[:-(4 + n_aux)] + refs[-4:]
    else:
        aux_refs = ()
    if has_scales:
        (q_ref, ks_ref, kmn_ref, kst_ref, vs_ref, vmn_ref, vst_ref,
         kbuf_ref, vbuf_ref, out_ref, acc_s, m_s, l_s) = refs
    else:
        (q_ref, ks_ref, vs_ref, kbuf_ref, vbuf_ref,
         out_ref, acc_s, m_s, l_s) = refs
        kmn_ref = kst_ref = vmn_ref = vst_ref = None
    b = pl.program_id(0)
    n = pl.program_id(2)
    T = block_size

    @pl.when(n == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        m_s[...] = jnp.full_like(m_s, NEG_INIT)
        l_s[...] = jnp.zeros_like(l_s)

    # Store blocks: each batch row of a continuous batch has its own number
    # of live blocks; steps past nb_valid[b] (and the final buffer step) skip.
    # Paged shards additionally skip blocks whose table entry is unassigned
    # (-1): their index map clamped to page 0, which holds some other row's
    # data, so the step must not touch the running softmax.
    live = n < nb_ref[b]
    if paged:
        live = live & (pt_ref[b, jnp.minimum(n, nb_total - 1)] >= 0)

    @pl.when(live)
    def _update():
        aux = tuple(r[...] for r in aux_refs)
        # --- decompress K in situ (VMEM), layout-owned decode ---
        kd = decode_k(ks_ref[0, 0, 0],
                      kmn_ref[0, 0, 0] if has_scales else None,
                      kst_ref[0, 0, 0] if has_scales else None,
                      *aux)  # [T, D]
        # --- scores on the MXU ---
        qg = q_ref[0].astype(jnp.float32)  # [G, D]
        s = jax.lax.dot_general(qg, kd, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # --- flash-decoding running softmax ---
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # [G, T]
        # --- decompress V in situ and accumulate ---
        vd = decode_v(vs_ref[0, 0, 0],
                      vmn_ref[0, 0, 0] if has_scales else None,
                      vst_ref[0, 0, 0] if has_scales else None,
                      *aux)  # [T, D]
        acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot(
            p, vd, preferred_element_type=jnp.float32)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1)
        m_s[...] = m_new

    # Final grid step: fold the raw buffer tail into the running softmax
    # (masked per row by buf_len) and emit the normalized output.
    @pl.when(n == nb_total)
    def _buffer_and_emit():
        qg = q_ref[0].astype(jnp.float32)  # [G, D]
        kb = kbuf_ref[0, 0].astype(jnp.float32)  # [T, D]
        s = jax.lax.dot_general(qg, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tpos = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
        ok = tpos < bl_ref[b]  # [1, T]
        s = jnp.where(ok, s, NEG_INIT)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * ok  # [G, T]
        vb = vbuf_ref[0, 0].astype(jnp.float32)
        acc = acc_s[...] * alpha[:, None] + jax.lax.dot(
            p, vb, preferred_element_type=jnp.float32)
        l = l_s[...] * alpha + jnp.sum(p, axis=1)
        out_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


def fused_cache_attention_pallas(
    q: Array,
    k_store: Array, k_min: Array, k_step: Array,
    v_store: Array, v_min: Array, v_step: Array,
    k_buf: Array, v_buf: Array,
    nb_valid: Array,  # i32 [B] per-row valid block counts (scalar broadcasts)
    buf_len: Array,   # i32 [B] per-row buffer lengths (scalar broadcasts)
    page_tab: Array | None = None,  # i32 [B, NB] paged: slot -> arena page
    *,
    tile,             # layouts.FusedTileSpec (memoized — see fused_tile_spec)
    block_size: int,
    scale: float | None = None,
    interpret: bool | str = "auto",
) -> Array:
    """Full decode attention over (store ∥ buffer) -> [B, Hq, D] f32.

    With ``page_tab`` the stores are a shared paged arena (batch extent 1,
    ``P`` pages on the block axis — DESIGN.md §10): the table joins
    ``nb_valid``/``buf_len`` as a scalar-prefetch operand and every store
    BlockSpec index map resolves logical block ``n`` of row ``b`` to
    ``page_tab[b, n]`` before the tile streams HBM→VMEM — the kernel body
    (decode, flash softmax) is untouched by paging.  Unassigned entries
    (-1) clamp to page 0 in the index map and their grid steps skip via
    the body's validity guard — which also covers shard-local tables
    (DESIGN.md §12) where blocks below ``nb_valid`` may be ``-1`` because
    another shard hosts them.
    """
    B, Hq, D = q.shape
    paged = page_tab is not None
    Hkv = k_store.shape[1]
    NB = page_tab.shape[1] if paged else k_store.shape[2]
    P = k_store.shape[2]  # physical block extent (arena pages when paged)
    G, T = Hq // Hkv, block_size
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _kernel,
        decode_k=tile.decode_k, decode_v=tile.decode_v,
        has_scales=tile.has_scales, n_aux=len(tile.aux),
        block_size=T, head_dim=D, scale=scale, nb_total=NB, paged=paged,
    )
    grid = (B, Hkv, NB + 1)

    # Index maps take the scalar-prefetch refs as trailing args; store tiles
    # clamp to the last block on the buffer step (loaded but unused).  The
    # paged variants get one extra trailing ref (the page table).
    in_specs = []
    inputs = []

    def fixed_map(*idx):
        return lambda b, h, n, *scalars: tuple(
            b if i == "b" else h if i == "h" else i for i in idx)

    in_specs.append(pl.BlockSpec((1, G, D), fixed_map("b", "h", 0)))
    inputs.append(q)

    def add_store(arr, tile_shape):
        r = len(tile_shape)
        if paged:
            def imap(b, h, n, nb, bl, pt, r=r):
                page = pt[b, jnp.minimum(n, NB - 1)]
                return (0, h, jnp.clip(page, 0, P - 1)) + (0,) * r
        else:
            def imap(b, h, n, nb, bl, r=r):
                return (b, h, jnp.minimum(n, NB - 1)) + (0,) * r
        in_specs.append(pl.BlockSpec((1, 1, 1) + tuple(tile_shape), imap))
        inputs.append(arr)

    add_store(k_store, tile.k_tile)
    if tile.has_scales:
        add_store(k_min, (D,))
        add_store(k_step, (D,))
    add_store(v_store, tile.v_tile)
    if tile.has_scales:
        add_store(v_min, (T,))
        add_store(v_step, (T,))
    for buf in (k_buf, v_buf):
        in_specs.append(pl.BlockSpec((1, 1, T, D), fixed_map("b", "h", 0, 0)))
        inputs.append(buf)
    for a in tile.aux:
        # Per-layer aux operand (e.g. a codebook LUT): block-invariant, one
        # whole-array tile staged into VMEM with a constant index map.
        arr = jnp.asarray(a)
        in_specs.append(pl.BlockSpec(arr.shape, fixed_map(*(0,) * arr.ndim)))
        inputs.append(arr)

    out_spec = pl.BlockSpec((1, G, D), fixed_map("b", "h", 0))
    scalars = [
        jnp.broadcast_to(jnp.atleast_1d(nb_valid), (B,)).astype(jnp.int32),
        jnp.broadcast_to(jnp.atleast_1d(buf_len), (B,)).astype(jnp.int32),
    ]
    if paged:
        scalars.append(page_tab.astype(jnp.int32))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(scalars),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(*scalars, *inputs)
