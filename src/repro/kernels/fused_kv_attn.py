"""Pallas TPU kernel: fused bit-unpack + dequantize + flash-decode attention.

The TPU realization of the paper's cache-resident decompression (§3.3.2):
packed u32 words stream HBM→VMEM once per block; unpacking (reshape/shift/
mask — no gathers, thanks to the no-straddle layout), dequantization, and the
attention matvec all happen inside the kernel on VMEM/VREG data.  The
decompressed K/V tiles are never written back to HBM — exactly the paper's
"decompressed data consumed in situ", with VMEM playing the role of GPU
shared memory and the MXU taking the dot products.

Grid: ``(B, Hkv, NB)``.  TPU grids execute sequentially with the last axis
innermost, so VMEM scratch carries the flash-decoding running state
``(m, l, acc)`` across the NB axis for each (batch, kv-head) pair — the same
trick flash-decoding uses, here doubling as the decompression consumer.

Block shapes keep the MXU happy when ``D`` and ``block_size`` are multiples
of 128/8; odd head_dims (112, 160, 80 in the assigned archs) are padded by
``ops.fused_decode_attention`` before the call.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INIT

Array = jax.Array


def _unpack_tile(words: Array, bits: int, n_codes: int) -> Array:
    """No-straddle unpack of a flat [W] u32 vector -> [n_codes] f32.

    Pure reshape/shift/mask — lowers to VPU element-wise ops, no gathers.
    """
    cpw = 32 // bits
    # iota is generated in-kernel (a captured host array would be a const).
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, cpw), 1) * jnp.uint32(bits)
    vals = (words[:, None] >> shifts) & jnp.uint32((1 << bits) - 1)
    return vals.reshape(-1)[:n_codes].astype(jnp.float32)


def _kernel(
    nb_valid_ref,  # scalar prefetch: i32 [B] per-row valid block counts
    q_ref,         # [1, G, D]
    ks_ref,        # [1, 1, 1, Wk] u32
    kmn_ref,       # [1, 1, 1, D]
    kst_ref,
    vs_ref,        # [1, 1, 1, Wv] u32
    vmn_ref,       # [1, 1, 1, T]
    vst_ref,
    acc_out,       # [1, G, D] f32
    m_out,         # [1, G]
    l_out,         # [1, G]
    acc_s,         # VMEM scratch [G, D] f32
    m_s,           # [G]
    l_s,           # [G]
    *,
    bits_k: int,
    bits_v: int,
    block_size: int,
    head_dim: int,
    scale: float,
    nb_total: int,
):
    n = pl.program_id(2)
    T, D = block_size, head_dim

    @pl.when(n == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        m_s[...] = jnp.full_like(m_s, NEG_INIT)
        l_s[...] = jnp.zeros_like(l_s)

    # Per-row validity: each batch row of a continuous batch has its own
    # number of live blocks (the scalar-prefetch ref is indexed by the batch
    # grid axis, available before the body runs).
    @pl.when(n < nb_valid_ref[pl.program_id(0)])
    def _update():
        # --- decompress K in situ (VMEM) ---
        k_codes = _unpack_tile(ks_ref[0, 0, 0, :], bits_k, T * D).reshape(T, D)
        k_mn = kmn_ref[0, 0, 0, :].astype(jnp.float32)
        k_st = kst_ref[0, 0, 0, :].astype(jnp.float32)
        kd = k_mn[None, :] + k_codes * k_st[None, :]  # [T, D]
        # --- scores on the MXU ---
        qg = q_ref[0].astype(jnp.float32)  # [G, D]
        s = jax.lax.dot_general(qg, kd, (((1,), (1,)), ((), ()))) * scale  # [G, T]
        # --- flash-decoding running softmax ---
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # [G, T]
        # --- decompress V in situ and accumulate ---
        v_codes = _unpack_tile(vs_ref[0, 0, 0, :], bits_v, T * D).reshape(T, D)
        v_mn = vmn_ref[0, 0, 0, :].astype(jnp.float32)
        v_st = vst_ref[0, 0, 0, :].astype(jnp.float32)
        vd = v_mn[:, None] + v_codes * v_st[:, None]  # [T, D]
        acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot(p, vd)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1)
        m_s[...] = m_new

    @pl.when(n == nb_total - 1)
    def _emit():
        acc_out[0] = acc_s[...]
        m_out[0] = m_s[...]
        l_out[0] = l_s[...]


def fused_decode_attention_pallas(
    q: Array,
    k_store: Array, k_min: Array, k_step: Array,
    v_store: Array, v_min: Array, v_step: Array,
    nb_valid: Array,  # i32 [B] per-row valid block counts (scalar broadcasts)
    *,
    bits_k: int, bits_v: int, block_size: int,
    scale: float | None = None,
    interpret: bool = True,
):
    """Returns (acc [B,Hq,D] f32 unnormalized, m [B,Hq], l [B,Hq])."""
    B, Hq, D = q.shape
    Hkv, NB, Wk = k_store.shape[1], k_store.shape[2], k_store.shape[3]
    Wv = v_store.shape[3]
    G, T = Hq // Hkv, block_size
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _kernel,
        bits_k=bits_k, bits_v=bits_v, block_size=T, head_dim=D,
        scale=scale, nb_total=NB,
    )
    grid = (B, Hkv, NB)
    out_shape = [
        jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq), jnp.float32),
    ]
# Index maps take the scalar-prefetch ref as a trailing arg.
    in_specs = [
        pl.BlockSpec((1, G, D), lambda b, h, n, nb: (b, h, 0)),
        pl.BlockSpec((1, 1, 1, Wk), lambda b, h, n, nb: (b, h, n, 0)),
        pl.BlockSpec((1, 1, 1, D), lambda b, h, n, nb: (b, h, n, 0)),
        pl.BlockSpec((1, 1, 1, D), lambda b, h, n, nb: (b, h, n, 0)),
        pl.BlockSpec((1, 1, 1, Wv), lambda b, h, n, nb: (b, h, n, 0)),
        pl.BlockSpec((1, 1, 1, T), lambda b, h, n, nb: (b, h, n, 0)),
        pl.BlockSpec((1, 1, 1, T), lambda b, h, n, nb: (b, h, n, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, G, D), lambda b, h, n, nb: (b, h, 0)),
        pl.BlockSpec((1, G), lambda b, h, n, nb: (b, h)),
        pl.BlockSpec((1, G), lambda b, h, n, nb: (b, h)),
    ]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.broadcast_to(jnp.atleast_1d(nb_valid), (B,)).astype(jnp.int32),
      q, k_store, k_min, k_step, v_store, v_min, v_step)
