"""Pallas kernel: Store-stage quantize + bit-pack (paper §3.2.2).

One grid step compresses one 2D block: the raw [T, D] tile streams HBM→VMEM,
min/max reduction, error-bounded quantization, and the no-straddle pack all
run in VMEM, and only the packed u32 words + fp scales go back to HBM —
the Store-stage mirror of cache-resident decompression.  The paper's
inclusive-scan + atomic-offset machinery is unnecessary here because uniform
per-block widths make every output offset affine in the block index
(DESIGN.md §2).

K blocks use BlockQuant units (min/max over the T tokens, per channel);
V blocks use TokenQuant units (min/max over D, per token).

Serving feeds this kernel from the chunked-admission loop (DESIGN.md §13):
each full prefill chunk flushes exactly one block, and on the fused paged
path the destination rows are pooled pages — the block compresses straight
into the arena with no dense-prompt staging, which is what holds peak
admission memory at O(chunk) instead of O(prompt).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _pack_tile(codes: Array, bits: int, W: int) -> Array:
    """No-straddle pack of flat [N] u32 codes -> [W] u32 words (in-VMEM)."""
    cpw = 32 // bits
    n = codes.shape[0]
    pad = W * cpw - n
    if pad:
        codes = jnp.concatenate([codes, jnp.zeros((pad,), jnp.uint32)])
    c = codes.reshape(W, cpw)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, cpw), 1) * jnp.uint32(bits)
    return jnp.sum(c << shifts, axis=1).astype(jnp.uint32)


def _kernel(x_ref, words_ref, mn_ref, st_ref, *, rel_scale, bits, token_wise, W):
    x = x_ref[0].astype(jnp.float32)  # [T, D]
    axis = 1 if token_wise else 0
    mn = jnp.min(x, axis=axis)
    mx = jnp.max(x, axis=axis)
    step = rel_scale * (mx - mn)
    safe = jnp.where(step > 0, step, 1.0)
    if token_wise:
        normalized = (x - mn[:, None]) / safe[:, None]
    else:
        normalized = (x - mn[None, :]) / safe[None, :]
    codes = jnp.clip(jnp.round(normalized), 0, 2**bits - 1).astype(jnp.uint32)
    words_ref[0] = _pack_tile(codes.reshape(-1), bits, W)
    mn_ref[0] = mn
    st_ref[0] = step


def quant_pack_pallas(
    x: Array,  # [NBLK, T, D] raw KV blocks
    rel_scale: float,
    bits: int,
    token_wise: bool,
    interpret: bool | str = "auto",
):
    """Returns (words u32 [NBLK, W], mn [NBLK, U], step [NBLK, U]) where
    U = T for token_wise (V) else D (K)."""
    NBLK, T, D = x.shape
    cpw = 32 // bits
    W = (T * D + cpw - 1) // cpw
    U = T if token_wise else D
    kernel = functools.partial(
        _kernel, rel_scale=rel_scale, bits=bits, token_wise=token_wise, W=W)
    return pl.pallas_call(
        kernel,
        grid=(NBLK,),
        in_specs=[pl.BlockSpec((1, T, D), lambda n: (n, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, W), lambda n: (n, 0)),
            pl.BlockSpec((1, U), lambda n: (n, 0)),
            pl.BlockSpec((1, U), lambda n: (n, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NBLK, W), jnp.uint32),
            jax.ShapeDtypeStruct((NBLK, U), jnp.float32),
            jax.ShapeDtypeStruct((NBLK, U), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x)
